//! Packed-code GEMM kernels v2: multiply two E2M1-quantized operands
//! directly in their packed storage form.
//!
//! This is the execution engine the recipe pipelines lower their Multiply
//! stage to, and (through the shared [`ikj_matmul`] driver) the engine the
//! serving path's `rowq_matmul` runs on. Both operands arrive packed along
//! the GeMM's reduction axis (blocks over their *columns*); the kernels
//! decode codes through the 256-entry byte-pair LUT — two elements per
//! table lookup — apply the per-block scale product as each K slab streams
//! through, and accumulate in f32. The kernel architecture (DESIGN.md §7):
//!
//! * **Byte-pair LUT decode** (`fp4::E2M1_BYTE_PAIR_LUT` via
//!   `QuantizedMat::decode_row_range`): one lookup emits a code byte's two
//!   elements, replacing v1's per-nibble shift/mask/match.
//! * **Register-blocked ikj microkernel** ([`MR`]-row × width output tile
//!   per K-slab pass): four output rows stream against each decoded ŵ slab
//!   row, so every slab load feeds four FMA streams instead of one.
//! * **Shared-slab decode** (row-sharded path): each weight K-slab is
//!   decoded *once* into a buffer all workers read, instead of once per
//!   worker chunk — v1 paid a T-fold redundant decode at T threads.
//! * **Column sharding** (skinny path, `parallel::par_col_chunks`): when
//!   the output has too few rows to split — the l=1 continuous-batching
//!   decode step — workers split the output *columns* instead, each
//!   decoding only its own stripe of every slab (no redundancy at all).
//! * **SIMD microkernels** (`quant::simd`, DESIGN.md §9): the axpy/dot
//!   inner loops and the byte-pair decode dispatch at runtime to
//!   AVX2/SSE2/scalar forms that are pinned bitwise to the scalar oracle
//!   (`tests/simd.rs`), so the ISA level never shows up in the results.
//!
//! Only bounded per-worker scratch is ever decoded: one K-slab stripe plus
//! an `MR`-row activation tile in the ikj kernels, and an `RB`-row
//! activation block plus a `JT`-row tile in the dot-form `_bt` kernel
//! (which now decodes each activation row once per GEMM, where v1
//! re-decoded it per column tile). The full dequantized f32 matrices of
//! the fake-quant path are never materialized — and since the pool/arena
//! refactor none of that scratch is heap-allocated per call either: every
//! decode slab, activation block, and JT tile checks out of the
//! worker-local `tensor::scratch` arena (allocation-free after warmup),
//! and every sharded region executes on the persistent worker pool in
//! `tensor::parallel` (zero per-call thread spawns; v1 keeps its original
//! per-call `vec![…]` slabs as the measured baseline). `tests/pool.rs`
//! pins both properties.
//!
//! **Bit-exactness contract:** for each output element the multiply/add
//! sequence (including the zero-operand skip) walks k in ascending order
//! with exactly the arithmetic of `Mat::matmul` / `Mat::matmul_bt` /
//! `Mat::matmul_at` applied to the dequantized operands, and neither row
//! nor column sharding nor the MR-row tiling changes any element's
//! accumulation order. So `packed_matmul(Q(x), Q(wᵀ))` is bit-identical to
//! `Q(x).dequantize().matmul(&Q(wᵀ).dequantize().transpose())`, at any
//! thread count — and bit-identical to the v1 kernels, kept here as
//! [`packed_matmul_v1`] for differential tests and the v1-vs-v2
//! microbenchmark. The property tests in `tests/packed_gemm.rs` pin all of
//! this.

use super::nvfp4::QuantizedMat;
use super::simd;
use crate::telemetry::{self, Span};
use crate::tensor::parallel::{self, min_cols_for as par_min_cols, min_rows_for as par_min_rows};
use crate::tensor::{scratch, Mat};
use std::panic::{self, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Barrier, RwLock};

/// K-slab width: a multiple of both the NVFP4 (16) and MXFP4 (32) block
/// sizes, matching `Mat::matmul`'s k-blocking.
const KB: usize = 64;

/// Row tile of the dot-form kernel's second operand.
const JT: usize = 32;

/// Activation row block of the dot-form kernel: â rows decode once per
/// block (bounding per-worker scratch at `RB · k` f32 instead of the whole
/// chunk) and are reused across every [`JT`] column tile of that block.
const RB: usize = 64;

/// Row register-blocking factor of the ikj microkernel: a 4-row output tile
/// reuses each decoded ŵ slab row four times from registers/L1.
const MR: usize = 4;

/// Decode rows `[j0, j1)` of packed ŵᵀ over K range `[k0, k1)` into the
/// k-major `slab` (`(k1-k0) × (j1-j0)`), the layout the ikj microkernel
/// streams. `wrow` is KB-wide scratch.
fn decode_wslab(
    wt: &QuantizedMat,
    j0: usize,
    j1: usize,
    k0: usize,
    k1: usize,
    wrow: &mut [f32; KB],
    slab: &mut [f32],
) {
    let width = j1 - j0;
    let kw = k1 - k0;
    debug_assert_eq!(slab.len(), kw * width);
    for j in j0..j1 {
        wt.decode_row_range(j, k0, k1, &mut wrow[..kw]);
        for (t, &v) in wrow[..kw].iter().enumerate() {
            slab[t * width + (j - j0)] = v;
        }
    }
}

/// Accumulate an `nr ≤ MR` row output tile against one decoded K-slab,
/// walking k ascending with exactly `Mat::matmul`'s per-row zero skip.
/// `xb` holds the decoded activation rows at stride [`KB`] (row r's slab
/// values at `xb[r*KB..r*KB+kw]`), `wslab` is k-major `kw × width`, and
/// `crows` the `nr × width` output tile. Fusing rows only interleaves
/// *independent* per-row FMA streams — each output element still sees its
/// own `c += a·w` sequence in the same k order — so the tiling (and where
/// tile boundaries fall) cannot change any element's bits. The streams
/// themselves run through the dispatched `simd::axpy`/`simd::axpy4`
/// kernels (bitwise-pinned to this loop's scalar form — DESIGN.md §9);
/// the zero-skip tests stay scalar per lane, so skip semantics are
/// untouched at every dispatch level.
fn slab_tile_ikj(xb: &[f32], kw: usize, nr: usize, wslab: &[f32], width: usize, crows: &mut [f32]) {
    debug_assert!((1..=MR).contains(&nr));
    debug_assert_eq!(crows.len(), nr * width);
    if nr == MR {
        let (c0, rest) = crows.split_at_mut(width);
        let (c1, rest) = rest.split_at_mut(width);
        let (c2, c3) = rest.split_at_mut(width);
        for t in 0..kw {
            let w = &wslab[t * width..(t + 1) * width];
            let (a0, a1, a2, a3) = (xb[t], xb[KB + t], xb[2 * KB + t], xb[3 * KB + t]);
            if a0 != 0.0 && a1 != 0.0 && a2 != 0.0 && a3 != 0.0 {
                // all four lanes live: one pass, four FMA streams per ŵ load
                simd::axpy4(c0, c1, c2, c3, [a0, a1, a2, a3], w);
            } else {
                // some lane hit matmul's zero skip: update live lanes one by
                // one (same per-element op sequence as the fused pass)
                for (av, c) in [(a0, &mut *c0), (a1, &mut *c1), (a2, &mut *c2), (a3, &mut *c3)] {
                    if av == 0.0 {
                        continue;
                    }
                    simd::axpy(c, av, w);
                }
            }
        }
    } else {
        for r in 0..nr {
            let crow = &mut crows[r * width..(r + 1) * width];
            for t in 0..kw {
                let av = xb[r * KB + t];
                if av == 0.0 {
                    continue;
                }
                simd::axpy(crow, av, &wslab[t * width..(t + 1) * width]);
            }
        }
    }
}

/// One column stripe `[col0, col0 + width)` of C = X̂·Ŵᵀ over all `l` output
/// rows: per K-slab, decode only this stripe's ŵ columns, then stream
/// MR-row microkernel tiles. Runs the sequential case (full width) and each
/// column-sharded worker (its own stripe — no decode is shared, so no
/// decode is redundant).
fn stripe_ikj<F>(
    l: usize,
    k: usize,
    decode_x: &F,
    wt: &QuantizedMat,
    col0: usize,
    width: usize,
    stripe: &mut [f32],
) where
    F: Fn(usize, usize, usize, &mut [f32]) + Sync,
{
    debug_assert_eq!(stripe.len(), l * width);
    // arena checkout, stale contents fine: decode_wslab fully rewrites the
    // [..kw*width] prefix before the microkernel reads it
    let mut wslab = scratch::take(KB * width);
    let mut wrow = [0.0f32; KB];
    let mut xb = [0.0f32; MR * KB];
    for k0 in (0..k).step_by(KB) {
        let k1 = (k0 + KB).min(k);
        let kw = k1 - k0;
        decode_wslab(wt, col0, col0 + width, k0, k1, &mut wrow, &mut wslab[..kw * width]);
        let mut i0 = 0usize;
        while i0 < l {
            let nr = (l - i0).min(MR);
            for r in 0..nr {
                decode_x(i0 + r, k0, k1, &mut xb[r * KB..r * KB + kw]);
            }
            slab_tile_ikj(
                &xb,
                kw,
                nr,
                &wslab[..kw * width],
                width,
                &mut stripe[i0 * width..(i0 + nr) * width],
            );
            i0 += nr;
        }
    }
}

/// One row-sharded worker of the shared-slab path. The worker whose chunk
/// starts at row 0 is the designated decoder: it write-locks the shared
/// slab and decodes the current K-slab exactly once; every worker then
/// joins the first barrier (so no reader can see a half-written or stale
/// slab) and consumes the slab under a read lock. The second barrier, after
/// every read guard has been dropped, fences readers-before-next-decode:
/// without it a descheduled worker could acquire its read lock only after
/// the decoder had already write-locked and overwritten the buffer with the
/// next slab (the write lock only blocks on guards already *held*, not
/// guards not yet acquired). All workers iterate the same `⌈k/KB⌉` slabs
/// and hit both barriers once per slab, so the barriers always have their
/// full complement. The decoded values are identical wherever they are
/// produced, so moving the decode to one worker cannot change any bits.
///
/// Panic discipline: a panicking worker must still join its remaining
/// barriers or every other worker hangs in `Barrier::wait` and the scope
/// join wedges the process. Both phases therefore run under
/// `catch_unwind`; a panic raises the shared `panicked` flag *before* the
/// worker's next barrier, every worker re-checks the flag right *after*
/// each barrier (so all of them observe the same state at the same
/// generation and return together), and the caller re-raises the panic
/// once the scope has joined. The original panic message still reaches
/// stderr through the normal panic hook at unwind time.
fn shared_slab_worker<F>(
    row0: usize,
    crows: &mut [f32],
    k: usize,
    n: usize,
    decode_x: &F,
    wt: &QuantizedMat,
    slab: &RwLock<&mut [f32]>,
    barrier: &Barrier,
    panicked: &AtomicBool,
) where
    F: Fn(usize, usize, usize, &mut [f32]) + Sync,
{
    let nrows = crows.len() / n;
    let mut wrow = [0.0f32; KB];
    let mut xb = [0.0f32; MR * KB];
    for k0 in (0..k).step_by(KB) {
        let k1 = (k0 + KB).min(k);
        let kw = k1 - k0;
        if row0 == 0 {
            let decode = panic::catch_unwind(AssertUnwindSafe(|| {
                let mut s = slab.write().expect("shared slab lock poisoned");
                decode_wslab(wt, 0, n, k0, k1, &mut wrow, &mut s[..kw * n]);
            }));
            if decode.is_err() {
                panicked.store(true, Ordering::Release);
            }
        }
        barrier.wait();
        if panicked.load(Ordering::Acquire) {
            return;
        }
        let compute = panic::catch_unwind(AssertUnwindSafe(|| {
            let s = slab.read().expect("shared slab lock poisoned");
            let wslab = &s[..kw * n];
            let mut i0 = 0usize;
            while i0 < nrows {
                let nr = (nrows - i0).min(MR);
                for r in 0..nr {
                    decode_x(row0 + i0 + r, k0, k1, &mut xb[r * KB..r * KB + kw]);
                }
                slab_tile_ikj(&xb, kw, nr, wslab, n, &mut crows[i0 * n..(i0 + nr) * n]);
                i0 += nr;
            }
        }));
        if compute.is_err() {
            panicked.store(true, Ordering::Release);
        }
        barrier.wait();
        if panicked.load(Ordering::Acquire) {
            return;
        }
    }
}

/// Shape-adaptive ikj driver behind [`packed_matmul`] and `rowq_matmul`
/// (which differ only in how an activation row decodes): row-sharded with a
/// shared once-decoded ŵ slab, column-sharded when the output is too skinny
/// to split by row (the l=1 serving decode step) or when columns engage
/// more workers, sequential otherwise.
///
/// Decision rule (DESIGN.md §7): the partition that engages more workers
/// wins. On a tie, the cheaper redundancy wins: the row path serializes one
/// KB×n weight decode per slab on the decoder worker (≈ T/l overhead on the
/// critical path), while the column path re-decodes the activation rows in
/// every stripe (≈ T/n overhead) — so rows are preferred iff `l ≥ n`. Every
/// branch computes each output element with the same ascending-k,
/// zero-skipping accumulation, so the choice never changes the result's
/// bits.
pub(crate) fn ikj_matmul<F>(l: usize, k: usize, n: usize, decode_x: &F, wt: &QuantizedMat) -> Mat
where
    F: Fn(usize, usize, usize, &mut [f32]) + Sync,
{
    let mut c = Mat::zeros(l, n);
    if l == 0 || n == 0 || k == 0 {
        return c;
    }
    // spans time, never compute: one relaxed load when telemetry is off
    let gemm_span = telemetry::span(Span::GemmIkj);
    let row_workers = parallel::worker_count(l, par_min_rows(k * n));
    let col_workers = parallel::worker_count(n, par_min_cols(l * k));
    let prefer_rows = row_workers > col_workers || (row_workers == col_workers && l >= n);
    // The row path's jobs synchronize on a per-slab barrier, so the batch
    // must run concurrently — which a *nested* parallel region cannot
    // guarantee (nested jobs run inline on one thread and the first
    // barrier would wedge). Nested calls take the barrier-free column
    // path instead; every branch computes identical bits, so the fallback
    // is invisible in the output.
    if row_workers > 1 && prefer_rows && !parallel::in_parallel_region() {
        // same chunk boundaries as par_row_chunks (scoped_row_chunks is its
        // splitting primitive), with one shared slab decoded once per K-slab.
        // The slab storage checks out of the caller's scratch arena (stale
        // contents fine: the decoder fully rewrites [..kw*n] before the
        // first barrier releases any reader); batches are serialized on the
        // pool, so no two GEMMs ever share this buffer.
        let mut slab_buf = scratch::take(KB * n);
        let slab: RwLock<&mut [f32]> = RwLock::new(&mut slab_buf);
        let barrier = Barrier::new(row_workers);
        let panicked = AtomicBool::new(false);
        parallel::scoped_row_chunks(&mut c.data, l, n, row_workers, |row0, chunk| {
            shared_slab_worker(row0, chunk, k, n, decode_x, wt, &slab, &barrier, &panicked)
        });
        assert!(
            !panicked.load(Ordering::Acquire),
            "ikj_matmul: a shared-slab worker panicked (see stderr for the original panic)"
        );
    } else {
        parallel::par_col_chunks(&mut c.data, l, n, par_min_cols(l * k), |col0, width, stripe| {
            stripe_ikj(l, k, decode_x, wt, col0, width, stripe);
        });
    }
    drop(gemm_span);
    c
}

/// C = X · W with X packed along its columns (K) and W supplied as a packed
/// **transpose** `wt` (n×k, also packed along its columns). Returns l×n f32.
///
/// v2 ikj kernel via [`ikj_matmul`]: byte-pair LUT decode, MR-row
/// register-blocked microkernel, shared-slab decode on the row-sharded
/// path, column sharding on skinny shapes.
pub fn packed_matmul(x: &QuantizedMat, wt: &QuantizedMat) -> Mat {
    assert_eq!(
        x.cols, wt.cols,
        "packed_matmul: K mismatch ({}x{} · ({}x{})ᵀ) — both operands must be packed along K",
        x.rows, x.cols, wt.rows, wt.cols
    );
    ikj_matmul(
        x.rows,
        x.cols,
        wt.rows,
        &|i: usize, k0: usize, k1: usize, out: &mut [f32]| x.decode_row_range(i, k0, k1, out),
        wt,
    )
}

/// The v1 (PR 1) forward kernel, kept verbatim as the differential-testing
/// and microbenchmark baseline for the v2 suite: per-nibble decode
/// (`decode_row_range_nibble`), per-worker-chunk slab decode, no register
/// blocking. `kernel_microbench` reports v1 vs v2 so the LUT / shared-slab
/// / microkernel gains stay measured, and `tests/packed_gemm.rs` pins
/// v1 == v2 bitwise. Not on any hot path.
pub fn packed_matmul_v1(x: &QuantizedMat, wt: &QuantizedMat) -> Mat {
    assert_eq!(
        x.cols, wt.cols,
        "packed_matmul_v1: K mismatch ({}x{} · ({}x{})ᵀ) — both operands must be packed along K",
        x.rows, x.cols, wt.rows, wt.cols
    );
    let (l, k, n) = (x.rows, x.cols, wt.rows);
    let mut c = Mat::zeros(l, n);
    parallel::par_row_chunks(&mut c.data, l, n, par_min_rows(k * n), |row0, crows| {
        let nrows = crows.len() / n.max(1);
        let mut wslab = vec![0.0f32; KB * n];
        let mut xbuf = [0.0f32; KB];
        let mut wrow = [0.0f32; KB];
        for k0 in (0..k).step_by(KB) {
            let k1 = (k0 + KB).min(k);
            let kw = k1 - k0;
            // v1: decode this K-slab of ŵ once per chunk (T-fold redundant)
            for j in 0..n {
                wt.decode_row_range_nibble(j, k0, k1, &mut wrow[..kw]);
                for (t, &v) in wrow[..kw].iter().enumerate() {
                    wslab[t * n + j] = v;
                }
            }
            for li in 0..nrows {
                x.decode_row_range_nibble(row0 + li, k0, k1, &mut xbuf[..kw]);
                let crow = &mut crows[li * n..(li + 1) * n];
                for (t, &av) in xbuf[..kw].iter().enumerate() {
                    if av == 0.0 {
                        continue;
                    }
                    let wrow_t = &wslab[t * n..(t + 1) * n];
                    for j in 0..n {
                        crow[j] += av * wrow_t[j];
                    }
                }
            }
        }
    });
    c
}

/// C = A · Bᵀ with both operands packed along their columns (the reduction
/// axis). Covers dgrad (∂X = D·Wᵀ, both packed along n) and — fed packed
/// transposes — wgrad (∂W = Xᵀ·D as `packed_matmul_bt(Q(xᵀ), Q(dᵀ))`, both
/// packed along l). Returns a.rows × b.rows f32.
///
/// Dot-form kernel mirroring `Mat::matmul_bt`: ascending-k dot products,
/// with b̂ decoded in row tiles of [`JT`]. v2 hoists the â decode out of the
/// column-tile loop — each row decodes exactly once (in [`RB`]-row blocks,
/// keeping scratch bounded) instead of `⌈n/JT⌉` times — and blocks [`MR`]
/// dot products per b̂ row stream, so every `brow[t]` load feeds four
/// accumulators. Total decode work per chunk drops from
/// `k·n + rows·k·⌈n/JT⌉` to `rows·k + k·n·⌈rows/RB⌉`, with per-worker
/// scratch capped at `(RB + JT)·k` f32.
pub fn packed_matmul_bt(a: &QuantizedMat, b: &QuantizedMat) -> Mat {
    assert_eq!(
        a.cols, b.cols,
        "packed_matmul_bt: K mismatch ({}x{} · ({}x{})ᵀ) — both operands must be packed along K",
        a.rows, a.cols, b.rows, b.cols
    );
    let (m, k, n) = (a.rows, a.cols, b.rows);
    let mut c = Mat::zeros(m, n);
    if m == 0 || n == 0 {
        return c;
    }
    let gemm_span = telemetry::span(Span::GemmBt);
    // worker count resolved through the same shared helpers as the ikj
    // driver (no local partition heuristics), then dispatched on the
    // persistent pool via the shared splitting primitive
    let workers = parallel::worker_count(m, par_min_rows(k * n));
    parallel::scoped_row_chunks(&mut c.data, m, n, workers, |row0, crows| {
        let nrows = crows.len() / n.max(1);
        // arena checkouts, stale contents fine: every abuf row and btile
        // row is decoded before the dot loops read it
        let mut abuf = scratch::take(RB.min(nrows).max(1) * k);
        let mut btile = scratch::take(JT * k);
        let mut ib0 = 0usize;
        while ib0 < nrows {
            let ib1 = (ib0 + RB).min(nrows);
            let bn = ib1 - ib0;
            // â rows of this block decode once, reused across every JT tile
            for li in 0..bn {
                a.decode_row_range(row0 + ib0 + li, 0, k, &mut abuf[li * k..(li + 1) * k]);
            }
            for j0 in (0..n).step_by(JT) {
                let j1 = (j0 + JT).min(n);
                for j in j0..j1 {
                    b.decode_row_range(j, 0, k, &mut btile[(j - j0) * k..(j - j0 + 1) * k]);
                }
                let mut i0 = 0usize;
                while i0 < bn {
                    let nr = (bn - i0).min(MR);
                    let arows = &abuf[i0 * k..(i0 + nr) * k];
                    for j in j0..j1 {
                        let brow = &btile[(j - j0) * k..(j - j0 + 1) * k];
                        if nr == MR {
                            // four dot products share each brow element;
                            // every accumulator still sums t = 0..k in
                            // ascending order (simd::dot4 keeps the four
                            // sums in four distinct lanes for that reason)
                            let [s0, s1, s2, s3] = simd::dot4(
                                &arows[..k],
                                &arows[k..2 * k],
                                &arows[2 * k..3 * k],
                                &arows[3 * k..],
                                brow,
                            );
                            crows[(ib0 + i0) * n + j] = s0;
                            crows[(ib0 + i0 + 1) * n + j] = s1;
                            crows[(ib0 + i0 + 2) * n + j] = s2;
                            crows[(ib0 + i0 + 3) * n + j] = s3;
                        } else {
                            for r in 0..nr {
                                let arow = &arows[r * k..(r + 1) * k];
                                let mut acc = 0.0f32;
                                for (t, &bv) in brow.iter().enumerate() {
                                    acc += arow[t] * bv;
                                }
                                crows[(ib0 + i0 + r) * n + j] = acc;
                            }
                        }
                    }
                    i0 += nr;
                }
            }
            ib0 = ib1;
        }
    });
    drop(gemm_span);
    c
}

/// term[r] = Σ_k mu[k] · q̂[r, k]: a quantized row vector times the packed
/// rows of `q` — the rank-one Correct term of the Averis pipelines
/// (`1·(μ̄_X W̄)` forward, `1·(μ̄_D W̄ᵀ)` dgrad), never materializing q̂.
/// Matches `Mat::matmul`'s zero-skip accumulation bit for bit. v2 shards
/// the output rows across the thread pool (each worker decodes its own q̂
/// rows); v1 ran sequentially in every Averis forward/dgrad Correct stage
/// regardless of `--threads`.
pub fn mu_times_packed_rows(mu: &[f32], q: &QuantizedMat) -> Vec<f32> {
    assert_eq!(mu.len(), q.cols, "mu_times_packed_rows: K mismatch");
    let mut out = vec![0.0f32; q.rows];
    let rows = q.rows;
    if rows == 0 {
        return out;
    }
    let gemm_span = telemetry::span(Span::GemmMu);
    // same shared worker-count helpers as every other kernel here, and
    // arena scratch for the per-worker decode row (fully rewritten per row)
    let workers = parallel::worker_count(rows, par_min_rows(q.cols));
    parallel::scoped_row_chunks(&mut out, rows, 1, workers, |row0, chunk| {
        let mut buf = scratch::take(q.cols);
        for (li, o) in chunk.iter_mut().enumerate() {
            q.decode_row_range(row0 + li, 0, q.cols, &mut buf);
            let mut acc = 0.0f32;
            for (t, &m) in mu.iter().enumerate() {
                if m == 0.0 {
                    continue;
                }
                acc += m * buf[t];
            }
            *o = acc;
        }
    });
    drop(gemm_span);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::nvfp4::Nvfp4Quantizer;
    use crate::tensor::Rng;

    fn assert_bits_eq(a: &Mat, b: &Mat, what: &str) {
        assert_eq!((a.rows, a.cols), (b.rows, b.cols), "{what}: shape");
        for (i, (x, y)) in a.data.iter().zip(b.data.iter()).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{what}: elem {i}: {x} vs {y}");
        }
    }

    #[test]
    fn packed_matmul_matches_fake_quant_bitwise() {
        let mut rng = Rng::new(90);
        for quant in [Nvfp4Quantizer::nvfp4(), Nvfp4Quantizer::mxfp4()] {
            for &(l, k, n) in &[(8usize, 32usize, 8usize), (5, 21, 3), (16, 8, 16)] {
                let x = Mat::randn(l, k, 1.0, &mut rng);
                let w = Mat::randn(k, n, 0.3, &mut rng);
                let fake = {
                    let xq = quant.quantize_dequant_rows(&x, None);
                    let wq = quant.quantize_dequant_cols(&w, None);
                    xq.matmul(&wq)
                };
                let packed = packed_matmul(
                    &quant.quantize_store(&x),
                    &quant.quantize_store(&w.transpose()),
                );
                assert_bits_eq(&packed, &fake, "fwd");
            }
        }
    }

    #[test]
    fn v1_baseline_matches_v2_bitwise() {
        // the kept v1 kernel is only a valid bench baseline if it still
        // computes exactly what v2 does
        let mut rng = Rng::new(93);
        for quant in [Nvfp4Quantizer::nvfp4(), Nvfp4Quantizer::mxfp4()] {
            for &(l, k, n) in &[(7usize, 67usize, 9usize), (1, 33, 40), (9, 128, 33)] {
                let x = Mat::randn(l, k, 1.0, &mut rng);
                let w = Mat::randn(k, n, 0.3, &mut rng);
                let xq = quant.quantize_store(&x);
                let wq = quant.quantize_store(&w.transpose());
                let v1 = packed_matmul_v1(&xq, &wq);
                let v2 = packed_matmul(&xq, &wq);
                assert_bits_eq(&v2, &v1, &format!("v1 vs v2 ({l},{k},{n})"));
            }
        }
    }

    #[test]
    fn microkernel_tile_remainders_match_fake_quant() {
        // l chosen so the MR=4 row tiling leaves remainders of 1, 2, and 3
        let mut rng = Rng::new(94);
        let quant = Nvfp4Quantizer::nvfp4();
        for &l in &[1usize, 2, 3, 5, 6, 7] {
            let x = Mat::randn(l, 70, 1.0, &mut rng);
            let w = Mat::randn(70, 12, 0.3, &mut rng);
            let fake = {
                let xq = quant.quantize_dequant_rows(&x, None);
                let wq = quant.quantize_dequant_cols(&w, None);
                xq.matmul(&wq)
            };
            let packed =
                packed_matmul(&quant.quantize_store(&x), &quant.quantize_store(&w.transpose()));
            assert_bits_eq(&packed, &fake, &format!("tile remainder l={l}"));
        }
    }

    #[test]
    fn packed_matmul_bt_matches_fake_quant_bitwise() {
        let mut rng = Rng::new(91);
        let quant = Nvfp4Quantizer::nvfp4();
        let d = Mat::randn(12, 24, 0.5, &mut rng);
        let w = Mat::randn(9, 24, 0.2, &mut rng);
        let fake = {
            let dq = quant.quantize_dequant_rows(&d, None);
            let wq = quant.quantize_dequant_rows(&w, None);
            dq.matmul_bt(&wq)
        };
        let packed = packed_matmul_bt(&quant.quantize_store(&d), &quant.quantize_store(&w));
        assert_bits_eq(&packed, &fake, "bt");
    }

    #[test]
    fn mu_product_matches_row_matmul_bitwise() {
        let mut rng = Rng::new(92);
        let quant = Nvfp4Quantizer::nvfp4();
        let w = Mat::randn(20, 13, 0.2, &mut rng);
        let mut mu: Vec<f32> = (0..20).map(|_| rng.normal()).collect();
        mu[3] = 0.0; // exercise the zero skip
        let wq_t = quant.quantize_store(&w.transpose());
        let term = mu_times_packed_rows(&mu, &wq_t);
        let fake = {
            let wq = quant.quantize_dequant_cols(&w, None);
            Mat::from_vec(1, 20, mu.clone()).matmul(&wq)
        };
        assert_eq!(term.len(), fake.data.len());
        for (a, b) in term.iter().zip(fake.data.iter()) {
            assert_eq!(a.to_bits(), b.to_bits(), "{a} vs {b}");
        }
    }

    #[test]
    fn mu_product_bit_identical_across_thread_counts() {
        // large enough that the new row sharding engages (cols small so
        // min_rows is small relative to rows)
        let mut rng = Rng::new(95);
        let quant = Nvfp4Quantizer::nvfp4();
        // packed transpose is 4096×256: min_rows = 2^18/256 = 1024, so 2/4
        // workers actually shard
        let w = Mat::randn(256, 4096, 0.2, &mut rng);
        let mu: Vec<f32> = (0..256).map(|_| rng.normal()).collect();
        let wq_t = quant.quantize_store(&w.transpose());
        let run = |threads: usize| {
            crate::tensor::parallel::set_threads(threads);
            let r = mu_times_packed_rows(&mu, &wq_t);
            crate::tensor::parallel::set_threads(0);
            r
        };
        let t1 = run(1);
        for t in [2usize, 4] {
            let tn = run(t);
            for (a, b) in t1.iter().zip(tn.iter()) {
                assert_eq!(a.to_bits(), b.to_bits(), "@{t} threads: {a} vs {b}");
            }
        }
    }
}
