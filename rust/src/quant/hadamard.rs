//! Tiled Hadamard transform — the NVIDIA-style outlier-smoothing baseline.
//!
//! The baseline reshapes X (l×m) into [l, m/T, T] tiles and applies an
//! orthonormal T×T Hadamard transform along the last axis (T = 16 in the
//! paper's Table 2). Because H/√T is orthogonal, applying it to both GeMM
//! operands preserves the product: (X Hᵀ)(H Wᵀᵀ) = X W, while spreading
//! within-tile outliers across the tile before quantization.
//!
//! The transform here is the fast Walsh–Hadamard (FWHT) butterfly — O(T log T)
//! per tile rather than a T×T matmul — which is the *optimized* form; Table 2
//! measures this implementation against Averis's single mean reduction.

use crate::tensor::Mat;

/// Dense T×T Hadamard matrix (Sylvester construction), scaled by 1/√T so it
/// is orthonormal. `t` must be a power of two.
pub fn hadamard_matrix(t: usize) -> Mat {
    assert!(t.is_power_of_two(), "Hadamard size must be a power of two");
    let mut h = Mat::from_vec(1, 1, vec![1.0]);
    let mut n = 1;
    while n < t {
        let mut next = Mat::zeros(2 * n, 2 * n);
        for i in 0..n {
            for j in 0..n {
                let v = h.at(i, j);
                *next.at_mut(i, j) = v;
                *next.at_mut(i, j + n) = v;
                *next.at_mut(i + n, j) = v;
                *next.at_mut(i + n, j + n) = -v;
            }
        }
        h = next;
        n *= 2;
    }
    let scale = 1.0 / (t as f32).sqrt();
    h.scale(scale);
    h
}

/// In-place fast Walsh–Hadamard transform of a length-T slice (T = 2^k),
/// normalized by 1/√T (so the transform is involutory: applying it twice
/// returns the input).
#[inline]
pub fn fwht_inplace(v: &mut [f32]) {
    let t = v.len();
    debug_assert!(t.is_power_of_two());
    let mut h = 1;
    while h < t {
        let step = h * 2;
        let mut i = 0;
        while i < t {
            for j in i..i + h {
                let x = v[j];
                let y = v[j + h];
                v[j] = x + y;
                v[j + h] = x - y;
            }
            i += step;
        }
        h = step;
    }
    let scale = 1.0 / (t as f32).sqrt();
    for x in v.iter_mut() {
        *x *= scale;
    }
}

/// Tiled Hadamard transform: apply the orthonormal T-point FWHT to every
/// consecutive tile of `tile` elements in every row of `x`. `x.cols` must be
/// divisible by `tile`. Returns a new matrix.
pub fn tiled_hadamard(x: &Mat, tile: usize) -> Mat {
    let mut out = x.clone();
    tiled_hadamard_inplace(&mut out, tile);
    out
}

/// In-place tiled Hadamard — the benchmarked hot path.
pub fn tiled_hadamard_inplace(x: &mut Mat, tile: usize) {
    assert!(tile.is_power_of_two());
    assert_eq!(x.cols % tile, 0, "cols {} not divisible by tile {}", x.cols, tile);
    let cols = x.cols;
    for i in 0..x.rows {
        let row = &mut x.data[i * cols..(i + 1) * cols];
        for chunk in row.chunks_exact_mut(tile) {
            fwht_inplace(chunk);
        }
    }
}

/// Inverse tiled Hadamard. The normalized FWHT is involutory, so the inverse
/// is the same transform; kept as a named function for call-site clarity.
pub fn tiled_hadamard_inverse(x: &Mat, tile: usize) -> Mat {
    tiled_hadamard(x, tile)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops::rel_error;
    use crate::tensor::Rng;

    #[test]
    fn hadamard_matrix_is_orthonormal() {
        for &t in &[2usize, 4, 16, 32] {
            let h = hadamard_matrix(t);
            let hht = h.matmul_bt(&h);
            for i in 0..t {
                for j in 0..t {
                    let e = if i == j { 1.0 } else { 0.0 };
                    assert!((hht.at(i, j) - e).abs() < 1e-5, "t={t} ({i},{j})");
                }
            }
        }
    }

    #[test]
    fn fwht_matches_dense_matrix() {
        let mut rng = Rng::new(31);
        let t = 16;
        let h = hadamard_matrix(t);
        let x = Mat::randn(1, t, 1.0, &mut rng);
        let dense = x.matmul_bt(&h); // x·Hᵀ ; H symmetric for Sylvester
        let mut fast = x.data.clone();
        fwht_inplace(&mut fast);
        for (a, b) in dense.data.iter().zip(fast.iter()) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn fwht_is_involutory() {
        let mut rng = Rng::new(32);
        let x = Mat::randn(4, 64, 1.0, &mut rng);
        let once = tiled_hadamard(&x, 16);
        let twice = tiled_hadamard(&once, 16);
        assert!(rel_error(&twice, &x) < 1e-5);
    }

    #[test]
    fn transform_preserves_norm() {
        let mut rng = Rng::new(33);
        let x = Mat::randn(8, 128, 1.0, &mut rng);
        let y = tiled_hadamard(&x, 16);
        assert!((x.fro_norm() - y.fro_norm()).abs() / x.fro_norm() < 1e-5);
    }

    #[test]
    fn smooths_single_outlier_across_tile() {
        // a lone spike of 16.0 becomes 16 entries of ±4.0 after the 16-point
        // orthonormal transform — dynamic range drops by √T
        let mut v = vec![0.0f32; 16];
        v[3] = 16.0;
        let x = Mat::from_vec(1, 16, v);
        let y = tiled_hadamard(&x, 16);
        let amax = y.abs_max();
        assert!((amax - 4.0).abs() < 1e-5, "amax {amax}");
    }

    #[test]
    fn gemm_invariance_under_paired_transform() {
        // (X Hᵀ)(H W) = X W since HᵀH = I
        let mut rng = Rng::new(34);
        let x = Mat::randn(8, 32, 1.0, &mut rng);
        let w = Mat::randn(32, 5, 1.0, &mut rng);
        let xh = tiled_hadamard(&x, 16);
        // apply H to W along K (rows): transform Wᵀ rows then transpose back
        let wh = tiled_hadamard(&w.transpose(), 16).transpose();
        let y1 = xh.matmul(&wh);
        let y2 = x.matmul(&w);
        assert!(rel_error(&y1, &y2) < 1e-4);
    }
}
