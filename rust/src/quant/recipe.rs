//! Quantization recipes — the five training configurations of the paper's
//! evaluation (Fig. 6 / Table 1) plus ablation variants.

use std::fmt;
use std::str::FromStr;

/// A full W4A4G4 training recipe.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum QuantRecipe {
    /// Full-precision reference (f32 on CPU standing in for BF16).
    Bf16,
    /// Vanilla NVFP4: blockwise E2M1+E4M3, no outlier treatment.
    Nvfp4,
    /// NVFP4 + tiled 16×16 Hadamard smoothing (NVIDIA-style baseline).
    Nvfp4Hadamard,
    /// NVFP4 + Averis mean–residual splitting (the paper's method).
    Averis,
    /// Averis + Hadamard on the residual (paper's combination row).
    AverisHadamard,
    /// MXFP4 ablation (block-32 E8M0 scales) — no outlier treatment.
    Mxfp4,
    /// Metis-style rank-1 SVD split ablation (spectral-space baseline).
    SvdSplit,
}

impl QuantRecipe {
    /// All recipes evaluated in Fig. 6 / Table 1.
    pub const PAPER_SET: [QuantRecipe; 5] = [
        QuantRecipe::Bf16,
        QuantRecipe::Nvfp4,
        QuantRecipe::Nvfp4Hadamard,
        QuantRecipe::Averis,
        QuantRecipe::AverisHadamard,
    ];

    /// Does this recipe quantize at all?
    pub fn is_quantized(self) -> bool {
        self != QuantRecipe::Bf16
    }

    /// Does this recipe apply the tiled Hadamard transform?
    pub fn uses_hadamard(self) -> bool {
        matches!(self, QuantRecipe::Nvfp4Hadamard | QuantRecipe::AverisHadamard)
    }

    /// Does this recipe apply mean–residual splitting?
    pub fn uses_mean_split(self) -> bool {
        matches!(self, QuantRecipe::Averis | QuantRecipe::AverisHadamard)
    }

    /// Artifact file stem for the AOT-compiled train step of this recipe.
    pub fn artifact_stem(self) -> &'static str {
        match self {
            QuantRecipe::Bf16 => "bf16",
            QuantRecipe::Nvfp4 => "nvfp4",
            QuantRecipe::Nvfp4Hadamard => "nvfp4_hadamard",
            QuantRecipe::Averis => "averis",
            QuantRecipe::AverisHadamard => "averis_hadamard",
            QuantRecipe::Mxfp4 => "mxfp4",
            QuantRecipe::SvdSplit => "svd_split",
        }
    }
}

impl fmt::Display for QuantRecipe {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            QuantRecipe::Bf16 => "BF16",
            QuantRecipe::Nvfp4 => "NVFP4",
            QuantRecipe::Nvfp4Hadamard => "NVFP4-Hadamard",
            QuantRecipe::Averis => "Averis",
            QuantRecipe::AverisHadamard => "Averis-Hadamard",
            QuantRecipe::Mxfp4 => "MXFP4",
            QuantRecipe::SvdSplit => "SVD-Split",
        };
        f.write_str(s)
    }
}

impl FromStr for QuantRecipe {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().replace('_', "-").as_str() {
            "bf16" | "fp32" | "full" => Ok(QuantRecipe::Bf16),
            "nvfp4" | "fp4" | "vanilla" => Ok(QuantRecipe::Nvfp4),
            "nvfp4-hadamard" | "hadamard" => Ok(QuantRecipe::Nvfp4Hadamard),
            "averis" => Ok(QuantRecipe::Averis),
            "averis-hadamard" => Ok(QuantRecipe::AverisHadamard),
            "mxfp4" => Ok(QuantRecipe::Mxfp4),
            "svd-split" | "svd" | "metis" => Ok(QuantRecipe::SvdSplit),
            other => Err(format!(
                "unknown recipe '{other}' (expected bf16|nvfp4|nvfp4-hadamard|averis|averis-hadamard|mxfp4|svd-split)"
            )),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for r in [
            QuantRecipe::Bf16,
            QuantRecipe::Nvfp4,
            QuantRecipe::Nvfp4Hadamard,
            QuantRecipe::Averis,
            QuantRecipe::AverisHadamard,
            QuantRecipe::Mxfp4,
            QuantRecipe::SvdSplit,
        ] {
            let s = r.to_string();
            assert_eq!(s.parse::<QuantRecipe>().unwrap(), r, "{s}");
        }
    }

    #[test]
    fn aliases() {
        assert_eq!("fp4".parse::<QuantRecipe>().unwrap(), QuantRecipe::Nvfp4);
        assert_eq!("metis".parse::<QuantRecipe>().unwrap(), QuantRecipe::SvdSplit);
        assert!("bogus".parse::<QuantRecipe>().is_err());
    }

    #[test]
    fn flags() {
        assert!(!QuantRecipe::Bf16.is_quantized());
        assert!(QuantRecipe::Averis.uses_mean_split());
        assert!(QuantRecipe::AverisHadamard.uses_hadamard());
        assert!(QuantRecipe::AverisHadamard.uses_mean_split());
        assert!(!QuantRecipe::Nvfp4.uses_hadamard());
    }
}
