//! Row-independent quantized execution — the serving-side counterpart of
//! the training engine in `quant::packed`.
//!
//! Training quantization derives one per-tensor scale from the *whole*
//! operand matrix, so a row's codes depend on every other row in the batch.
//! That is fine for training (the batch is the unit of work) but breaks the
//! serving contract: a KV-cached decode step sees only the new token rows,
//! and its logits must be bit-identical to a full-context recomputation no
//! matter how the rows were batched. [`RowQuantMat`] therefore quantizes
//! **each row as its own tensor** (per-row tensor scale + per-row block
//! scales along K), making every row's quantized value a pure function of
//! that row alone. Prefill-vs-incremental parity and continuous-batching
//! determinism both reduce to this property.
//!
//! [`FrozenLinear`] is the serving linear layer built on top: the weight is
//! packed to E2M1 codes **once** (never re-quantized per call), and the
//! Averis mean–residual split (paper Eqs. 8–10) is conditioned with a
//! *frozen* calibration mean μ̂ instead of the batch column mean — at decode
//! time the token dimension is l = 1, where the batch-mean split degenerates
//! (the residual would vanish into the mean operand). This is the static
//! bias-vector treatment of *Massive Spikes in LLMs are Bias Vectors*
//! (Chen et al.): Ŷ = Q(X − 1·μ̂ᵀ)·Ŵ + 1·(μ̂_q·Ŵ), with the rank-one term
//! precomputed at pack time.
//!
//! Bit-exactness contract (mirrors `quant::packed`): every output element
//! accumulates k in ascending order with `Mat::matmul`'s zero-skip, and row
//! sharding never reorders a row's accumulation, so results are
//! bit-identical at any thread count.

use super::nvfp4::{Nvfp4Quantizer, QuantizedMat};
use super::packed::mu_times_packed_rows;
use crate::tensor::{scratch, Mat};

/// A matrix quantized row by row: each row carries its own tensor scale and
/// block scales, so its codes are independent of every other row.
#[derive(Clone, Debug)]
pub struct RowQuantMat {
    pub rows: usize,
    pub cols: usize,
    /// one single-row [`QuantizedMat`] per logical row
    rowmats: Vec<QuantizedMat>,
}

impl RowQuantMat {
    /// Quantize each row of `x` as its own tensor (RTNE). Row `i` of the
    /// result is bit-identical to `quant.quantize_store` of the 1×cols
    /// matrix holding row `i` — the property the decode-parity tests pin.
    pub fn quantize(quant: &Nvfp4Quantizer, x: &Mat) -> RowQuantMat {
        Self::quantize_with(quant, x, None)
    }

    /// Quantize each row of `x − 1·μᵀ` without materializing the centered
    /// matrix: the subtraction happens in the per-row copy that quantization
    /// needs anyway. Bit-identical to `quantize(quant, &centered)` — the
    /// decode hot path (`FrozenLinear::forward`) runs this once per call.
    pub fn quantize_centered(quant: &Nvfp4Quantizer, x: &Mat, mu: &[f32]) -> RowQuantMat {
        assert_eq!(mu.len(), x.cols, "quantize_centered: μ length must match cols");
        Self::quantize_with(quant, x, Some(mu))
    }

    /// Shared row-by-row packing behind [`Self::quantize`] and
    /// [`Self::quantize_centered`]: every row stages through **one**
    /// scratch-arena row matrix instead of a fresh `Vec` per row, so the
    /// per-call decode tax of `FrozenLinear::forward` (which runs this on
    /// every serving step) is just the packed codes it actually produces.
    /// The staged copy (and optional μ subtraction) is arithmetic-identical
    /// to the old per-row materialization, so no bits change. The
    /// `quantize_store` call it stages into rides the dispatched SIMD
    /// quantize/pack kernel (DESIGN.md §9) — per-row serving quantization
    /// gets the vector path with no code here.
    fn quantize_with(quant: &Nvfp4Quantizer, x: &Mat, mu: Option<&[f32]>) -> RowQuantMat {
        let mut tmp = Mat::from_vec(1, x.cols, scratch::take_vec(x.cols));
        let rowmats = (0..x.rows)
            .map(|i| {
                tmp.data.copy_from_slice(x.row(i));
                if let Some(mu) = mu {
                    for (r, &m) in tmp.data.iter_mut().zip(mu.iter()) {
                        *r -= m;
                    }
                }
                quant.quantize_store(&tmp)
            })
            .collect();
        scratch::give(std::mem::take(&mut tmp.data));
        RowQuantMat { rows: x.rows, cols: x.cols, rowmats }
    }

    /// Decode columns `[j0, j1)` of row `i` (same arithmetic as
    /// `QuantizedMat::decode_row_range`).
    #[inline]
    pub fn decode_row_range(&self, i: usize, j0: usize, j1: usize, out: &mut [f32]) {
        self.rowmats[i].decode_row_range(0, j0, j1, out)
    }

    /// Dequantize back to f32 (diagnostics).
    pub fn dequantize(&self) -> Mat {
        let mut out = Mat::zeros(self.rows, self.cols);
        let cols = self.cols;
        for i in 0..self.rows {
            self.decode_row_range(i, 0, cols, &mut out.data[i * cols..(i + 1) * cols]);
        }
        out
    }
}

/// C = X · W with X row-quantized and W supplied as a packed transpose
/// `wt` (n×k, packed along its columns = K). Returns l×n f32.
///
/// Runs on the same v2 ikj driver as `quant::packed::packed_matmul`
/// (byte-pair LUT decode, MR-row microkernel, shared-slab decode on the
/// row-sharded path) — the two kernels differ only in how an activation
/// row decodes. Crucially for serving, skinny step batches — the l=1
/// decode of `FrozenLinear::forward` — now shard the output *columns*
/// across the thread pool instead of falling back to one thread, with each
/// worker decoding only its own stripe of every weight K-slab.
pub fn rowq_matmul(x: &RowQuantMat, wt: &QuantizedMat) -> Mat {
    assert_eq!(
        x.cols, wt.cols,
        "rowq_matmul: K mismatch ({}x{} · ({}x{})ᵀ) — both operands must be packed along K",
        x.rows, x.cols, wt.rows, wt.cols
    );
    super::packed::ikj_matmul(
        x.rows,
        x.cols,
        wt.rows,
        &|i: usize, k0: usize, k1: usize, out: &mut [f32]| x.decode_row_range(i, k0, k1, out),
        wt,
    )
}

/// A serving linear layer: weight packed once, activations row-quantized per
/// call, mean bias handled by a frozen calibration mean.
///
///   Y = Q(X − 1·μ̂ᵀ) · Ŵ + 1·(μ̂_q·Ŵ)
///
/// With μ̂ = 0 this degenerates to plain row-quantized NVFP4 (used for
/// operands whose calibration mean is not captured, e.g. attention outputs).
#[derive(Clone, Debug)]
pub struct FrozenLinear {
    quant: Nvfp4Quantizer,
    /// packed Wᵀ: out_dim × in_dim, blocks along in_dim (the GEMM's K axis)
    pub wt: QuantizedMat,
    /// frozen calibration mean, RTNE-quantized (len in_dim)
    pub mu_q: Vec<f32>,
    /// precomputed rank-one term μ̂_q·Ŵ (len out_dim)
    pub mu_term: Vec<f32>,
}

impl FrozenLinear {
    /// Pack `w` (in_dim × out_dim, the model's weight convention) with a
    /// frozen calibration mean `mu` over the input features.
    pub fn new(w: &Mat, mu: &[f32], quant: Nvfp4Quantizer) -> FrozenLinear {
        assert_eq!(mu.len(), w.rows, "FrozenLinear: μ̂ length must match in_dim");
        let wt = quant.quantize_store(&w.transpose());
        let mu_q = quant.quantize_dequant_vec(mu);
        let mu_term = mu_times_packed_rows(&mu_q, &wt);
        FrozenLinear { quant, wt, mu_q, mu_term }
    }

    /// Rebuild from serialized parts (the rank-one term is recomputed — it
    /// is a pure function of the stored codes and μ̂).
    pub fn from_parts(wt: QuantizedMat, mu_q: Vec<f32>, quant: Nvfp4Quantizer) -> FrozenLinear {
        assert_eq!(mu_q.len(), wt.cols, "FrozenLinear: μ̂ length must match packed K");
        let mu_term = mu_times_packed_rows(&mu_q, &wt);
        FrozenLinear { quant, wt, mu_q, mu_term }
    }

    pub fn in_dim(&self) -> usize {
        self.wt.cols
    }

    pub fn out_dim(&self) -> usize {
        self.wt.rows
    }

    /// Packed storage footprint (codes + scales + μ̂), for checkpoint stats.
    pub fn storage_bytes(&self) -> usize {
        self.wt.storage_bytes() + 4 * self.mu_q.len()
    }

    /// Row-independent quantized forward: each row of `x` quantizes as its
    /// own tensor, so Y's row i depends only on x's row i (and the packed
    /// weight). Bit-identical at any thread count and any row batching.
    pub fn forward(&self, x: &Mat) -> Mat {
        assert_eq!(x.cols, self.in_dim(), "FrozenLinear: input width mismatch");
        let q = RowQuantMat::quantize_centered(&self.quant, x, &self.mu_q);
        let mut y = rowq_matmul(&q, &self.wt);
        y.add_row_vec(&self.mu_term);
        y
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops::rel_error;
    use crate::tensor::{parallel, Rng};

    fn mean_biased(l: usize, m: usize, bias: f32, noise: f32, rng: &mut Rng) -> Mat {
        let mut x = Mat::randn(l, m, noise, rng);
        let mut mu = vec![0.0f32; m];
        for (j, v) in mu.iter_mut().enumerate() {
            if j % 16 == 3 {
                *v = bias * (1.0 + 0.3 * rng.normal());
            }
        }
        x.add_row_vec(&mu);
        x
    }

    #[test]
    fn row_quantization_is_row_independent() {
        // quantizing a row inside a batch == quantizing it alone
        let mut rng = Rng::new(200);
        let quant = Nvfp4Quantizer::nvfp4();
        let x = mean_biased(8, 48, 3.0, 0.5, &mut rng);
        let full = RowQuantMat::quantize(&quant, &x).dequantize();
        for i in 0..x.rows {
            let solo = RowQuantMat::quantize(&quant, &x.rows_slice(i, 1)).dequantize();
            for (a, b) in full.row(i).iter().zip(solo.row(0).iter()) {
                assert_eq!(a.to_bits(), b.to_bits(), "row {i}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn rowq_matmul_matches_dequantized_reference_bitwise() {
        let mut rng = Rng::new(201);
        let quant = Nvfp4Quantizer::nvfp4();
        for &(l, k, n) in &[(5usize, 21usize, 3usize), (8, 64, 16), (1, 33, 7)] {
            let x = Mat::randn(l, k, 1.0, &mut rng);
            let w = Mat::randn(k, n, 0.3, &mut rng);
            let q = RowQuantMat::quantize(&quant, &x);
            let wt = quant.quantize_store(&w.transpose());
            let packed = rowq_matmul(&q, &wt);
            let reference = q.dequantize().matmul(&wt.dequantize().transpose());
            for (i, (a, b)) in packed.data.iter().zip(reference.data.iter()).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "({l},{k},{n}) elem {i}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn rowq_matmul_bit_identical_across_thread_counts() {
        let mut rng = Rng::new(202);
        let quant = Nvfp4Quantizer::nvfp4();
        let x = Mat::randn(96, 160, 1.0, &mut rng);
        let w = Mat::randn(160, 80, 0.2, &mut rng);
        let q = RowQuantMat::quantize(&quant, &x);
        let wt = quant.quantize_store(&w.transpose());
        let run = |threads: usize| {
            parallel::set_threads(threads);
            let r = rowq_matmul(&q, &wt);
            parallel::set_threads(0);
            r
        };
        let c1 = run(1);
        assert_eq!(c1.data, run(2).data);
        assert_eq!(c1.data, run(4).data);
    }

    #[test]
    fn quantize_centered_matches_explicit_centering_bitwise() {
        let mut rng = Rng::new(206);
        let quant = Nvfp4Quantizer::nvfp4();
        let x = mean_biased(7, 33, 2.0, 0.5, &mut rng);
        let mu: Vec<f32> = (0..33).map(|_| rng.normal()).collect();
        let mut centered = x.clone();
        centered.sub_row_vec(&mu);
        let a = RowQuantMat::quantize_centered(&quant, &x, &mu).dequantize();
        let b = RowQuantMat::quantize(&quant, &centered).dequantize();
        for (u, v) in a.data.iter().zip(b.data.iter()) {
            assert_eq!(u.to_bits(), v.to_bits(), "{u} vs {v}");
        }
    }

    #[test]
    fn frozen_mean_beats_plain_on_mean_biased_rows() {
        // the serving analogue of the Averis headline: conditioning with a
        // frozen calibration μ̂ recovers the split's accuracy at decode time
        let mut rng = Rng::new(203);
        let x = mean_biased(64, 96, 4.0, 0.3, &mut rng);
        let w = Mat::randn(96, 32, 0.1, &mut rng);
        let exact = x.matmul(&w);
        let quant = Nvfp4Quantizer::nvfp4();
        // calibration mean from an independent sample of the same regime
        let calib = mean_biased(64, 96, 4.0, 0.3, &mut rng).col_mean();
        let frozen = FrozenLinear::new(&w, &calib, quant);
        let plain = FrozenLinear::new(&w, &[0.0; 96], quant);
        let e_frozen = rel_error(&frozen.forward(&x), &exact);
        let e_plain = rel_error(&plain.forward(&x), &exact);
        assert!(
            e_frozen < e_plain,
            "frozen-μ̂ split should beat plain row quantization: {e_frozen} vs {e_plain}"
        );
    }

    #[test]
    fn frozen_linear_rows_are_independent() {
        let mut rng = Rng::new(204);
        let x = mean_biased(6, 48, 2.0, 0.5, &mut rng);
        let w = Mat::randn(48, 16, 0.2, &mut rng);
        let mu = x.col_mean();
        let lin = FrozenLinear::new(&w, &mu, Nvfp4Quantizer::nvfp4());
        let batched = lin.forward(&x);
        for i in 0..x.rows {
            let solo = lin.forward(&x.rows_slice(i, 1));
            for (a, b) in batched.row(i).iter().zip(solo.row(0).iter()) {
                assert_eq!(a.to_bits(), b.to_bits(), "row {i}");
            }
        }
    }

    #[test]
    fn from_parts_roundtrip_matches() {
        let mut rng = Rng::new(205);
        let x = Mat::randn(4, 32, 1.0, &mut rng);
        let w = Mat::randn(32, 8, 0.2, &mut rng);
        let mu: Vec<f32> = (0..32).map(|_| rng.normal()).collect();
        let quant = Nvfp4Quantizer::nvfp4();
        let a = FrozenLinear::new(&w, &mu, quant);
        let b = FrozenLinear::from_parts(a.wt.clone(), a.mu_q.clone(), quant);
        assert_eq!(a.forward(&x).data, b.forward(&x).data);
    }
}
