//! **Averis** — Averaging-Induced Residual Splitting (the paper's method, §3).
//!
//! Quantization-sensitive activation outliers are predominantly driven by a
//! coherent rank-one mean component M_X = 1·μ_Xᵀ (paper §2). Averis therefore
//! factors each quantized GeMM operand into column-mean + residual and
//! quantizes them separately:
//!
//!   forward (Eq. 8):   Ŷ          = 1·(μ̄_X · W̄) + X̄_R · W̄
//!   dgrad   (Eq. 9):   ∂L/∂X^     = 1·(μ̄_D · W̄ᵀ) + D̄_R · W̄ᵀ
//!   wgrad   (Eq. 10):  ∂L/∂W^     = X̄_Rᵀ · D̄_R + l · μ̄_Xᵀ · μ̄_D
//!
//! The cross terms in Eq. 10 vanish exactly because the residuals are
//! column-centered. Cost over vanilla quantization: one columnwise mean
//! reduction + one broadcast subtract per operand (no transforms, no SVD).

use super::nvfp4::Nvfp4Quantizer;
use crate::tensor::{Mat, Rng};

/// Split a matrix into (column-mean vector, residual matrix):
/// μ[j] = (1/l)·Σᵢ X[i,j],  X_R = X − 1·μᵀ.
/// This is the entire preprocessing cost of Averis (Table 2 measures it).
pub fn mean_residual_split(x: &Mat) -> (Vec<f32>, Mat) {
    let mu = x.col_mean();
    let mut residual = x.clone();
    residual.sub_row_vec(&mu);
    (mu, residual)
}

/// In-place split: `x` becomes the residual; returns μ. Saves one allocation
/// on the training hot path.
pub fn mean_residual_split_inplace(x: &mut Mat) -> Vec<f32> {
    let mu = x.col_mean();
    x.sub_row_vec(&mu);
    mu
}

/// Averis forward GeMM (Eq. 8): quantize μ_X, X_R and W separately, compute
///   Ŷ = 1·(μ̄_X W̄) + X̄_R W̄.
///
/// `w_quant` lets the caller pass an already-quantized weight (weights are
/// quantized once per step, not once per GeMM).
pub fn averis_forward(
    x: &Mat,
    w: &Mat,
    quant: &Nvfp4Quantizer,
    w_quant: Option<&Mat>,
) -> Mat {
    let (mu, mut xr) = mean_residual_split(x);
    let mu_q = quant.quantize_dequant_vec(&mu);
    quant.quantize_dequant_rows_inplace(&mut xr, None);
    let wq_owned;
    let wq = match w_quant {
        Some(m) => m,
        None => {
            wq_owned = quant.quantize_dequant_cols(w, None);
            &wq_owned
        }
    };
    // residual GeMM
    let mut y = xr.matmul(wq);
    // rank-one term: (μ̄ W̄) is 1×n, broadcast-added to every row
    let mu_mat = Mat::from_vec(1, mu_q.len(), mu_q);
    let mu_w = mu_mat.matmul(wq); // 1×n
    y.add_row_vec(&mu_w.data);
    y
}

/// Averis input-gradient GeMM (Eq. 9): split D, quantize with stochastic
/// rounding (paper §4: SR on backward gradient operands), compute
///   ∂L/∂X = 1·(μ̄_D W̄ᵀ) + D̄_R W̄ᵀ.
pub fn averis_dgrad(
    d: &Mat,
    w: &Mat,
    quant_sr: &Nvfp4Quantizer,
    quant_w: &Nvfp4Quantizer,
    rng: &mut Rng,
) -> Mat {
    let (mu_d, mut dr) = mean_residual_split(d);
    let mu_q = quant_sr.quantize_dequant_vec(&mu_d);
    quant_sr.quantize_dequant_rows_inplace(&mut dr, Some(rng));
    // W quantized along K = m? For dgrad, ∂X = D Wᵀ: reduction over n, i.e.
    // W's columns ⇒ quantize W along rows of Wᵀ = cols of W... we quantize Wᵀ
    // rows = contiguous after transpose. Use matmul_bt with W quantized along
    // its column axis (the reduction axis of this GeMM).
    let wq = quant_w.quantize_dequant_rows(w, None); // blocks along n (K of this GeMM)
    let mut dx = dr.matmul_bt(&wq);
    let mu_mat = Mat::from_vec(1, mu_q.len(), mu_q);
    let mu_wt = mu_mat.matmul_bt(&wq); // 1×m
    dx.add_row_vec(&mu_wt.data);
    dx
}

/// Averis weight-gradient GeMM (Eq. 10):
///   ∂L/∂W = X̄_Rᵀ D̄_R + l·μ̄_Xᵀ μ̄_D.
/// Both operands quantized along K = l (their row axis ⇒ `quantize_dequant_cols`).
pub fn averis_wgrad(
    x: &Mat,
    d: &Mat,
    quant_x: &Nvfp4Quantizer,
    quant_d_sr: &Nvfp4Quantizer,
    rng: &mut Rng,
) -> Mat {
    assert_eq!(x.rows, d.rows, "wgrad: token dims must match");
    let l = x.rows;
    let (mu_x, xr) = mean_residual_split(x);
    let (mu_d, dr) = mean_residual_split(d);
    let mu_x_q = quant_x.quantize_dequant_vec(&mu_x);
    let mu_d_q = quant_d_sr.quantize_dequant_vec(&mu_d);
    let xr_q = quant_x.quantize_dequant_cols(&xr, None);
    let dr_q = quant_d_sr.quantize_dequant_cols(&dr, Some(rng));
    // X_Rᵀ D_R : m×n
    let mut dw = xr_q.matmul_at(&dr_q);
    // + l · μ_Xᵀ μ_D (outer product)
    let n = mu_d_q.len();
    for (i, &mx) in mu_x_q.iter().enumerate() {
        if mx == 0.0 {
            continue;
        }
        let row = &mut dw.data[i * n..(i + 1) * n];
        let c = l as f32 * mx;
        for (r, &md) in row.iter_mut().zip(mu_d_q.iter()) {
            *r += c * md;
        }
    }
    dw
}

/// Relative quantization error of plain NVFP4 vs Averis-split NVFP4 on a
/// matrix — the App. D diagnostic (and a quickstart demo).
pub fn split_vs_plain_error(x: &Mat, quant: &Nvfp4Quantizer) -> (f32, f32) {
    use crate::tensor::ops::rel_error;
    let plain = quant.quantize_dequant_rows(x, None);
    let plain_err = rel_error(&plain, x);

    let (mu, mut xr) = mean_residual_split(x);
    let mu_q = quant.quantize_dequant_vec(&mu);
    quant.quantize_dequant_rows_inplace(&mut xr, None);
    xr.add_row_vec(&mu_q); // reconstruct
    let split_err = rel_error(&xr, x);
    (plain_err, split_err)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops::rel_error;
    use crate::tensor::Rng;

    /// Synthetic "mean-biased" activation in the paper's §2.3 regime:
    /// a few outlier feature columns carry a large coherent mean (these set
    /// the block scales and crush their blocks' long tail), the rest are
    /// near-centered noise.
    fn mean_biased(l: usize, m: usize, bias: f32, noise: f32, rng: &mut Rng) -> Mat {
        let mut x = Mat::randn(l, m, noise, rng);
        let mut mu = vec![0.0f32; m];
        for (j, v) in mu.iter_mut().enumerate() {
            if j % 16 == 3 {
                *v = bias * (1.0 + 0.3 * rng.normal());
            }
        }
        x.add_row_vec(&mu);
        x
    }

    #[test]
    fn split_reconstructs_exactly() {
        let mut rng = Rng::new(50);
        let x = mean_biased(32, 64, 3.0, 0.5, &mut rng);
        let (mu, mut xr) = mean_residual_split(&x);
        xr.add_row_vec(&mu);
        assert!(rel_error(&xr, &x) < 1e-6);
    }

    #[test]
    fn residual_is_column_centered() {
        let mut rng = Rng::new(51);
        let x = mean_biased(40, 24, 2.0, 1.0, &mut rng);
        let (_, xr) = mean_residual_split(&x);
        for m in xr.col_mean() {
            assert!(m.abs() < 1e-5);
        }
    }

    #[test]
    fn averis_beats_plain_on_mean_biased_data() {
        let mut rng = Rng::new(52);
        let x = mean_biased(128, 256, 4.0, 0.3, &mut rng);
        let quant = Nvfp4Quantizer::nvfp4();
        let (plain, split) = split_vs_plain_error(&x, &quant);
        assert!(
            split < plain * 0.7,
            "Averis should cut quant error on mean-biased data: plain {plain} split {split}"
        );
    }

    #[test]
    fn averis_roughly_neutral_on_centered_data() {
        // when there is no mean bias, splitting should not hurt much
        let mut rng = Rng::new(53);
        let x = Mat::randn(128, 256, 1.0, &mut rng);
        let quant = Nvfp4Quantizer::nvfp4();
        let (plain, split) = split_vs_plain_error(&x, &quant);
        assert!(split < plain * 1.3, "plain {plain} split {split}");
    }

    #[test]
    fn forward_matches_exact_gemm_closely() {
        let mut rng = Rng::new(54);
        let x = mean_biased(64, 96, 3.0, 0.4, &mut rng);
        let w = Mat::randn(96, 32, 0.1, &mut rng);
        let quant = Nvfp4Quantizer::nvfp4();
        let exact = x.matmul(&w);
        let averis = averis_forward(&x, &w, &quant, None);
        let plain = {
            let xq = quant.quantize_dequant_rows(&x, None);
            let wq = quant.quantize_dequant_cols(&w, None);
            xq.matmul(&wq)
        };
        let e_averis = rel_error(&averis, &exact);
        let e_plain = rel_error(&plain, &exact);
        assert!(
            e_averis < e_plain,
            "Averis fwd GeMM should beat vanilla: averis {e_averis} plain {e_plain}"
        );
    }

    #[test]
    fn wgrad_cross_terms_vanish() {
        // Eq. 10 exactness in full precision: X_Rᵀ D_R + l μ_Xᵀ μ_D = Xᵀ D
        let mut rng = Rng::new(55);
        let x = mean_biased(48, 32, 2.0, 1.0, &mut rng);
        let d = mean_biased(48, 24, 0.5, 1.0, &mut rng);
        let exact = x.matmul_at(&d);
        let (mu_x, xr) = mean_residual_split(&x);
        let (mu_d, dr) = mean_residual_split(&d);
        let mut recon = xr.matmul_at(&dr);
        let l = x.rows as f32;
        for i in 0..32 {
            for j in 0..24 {
                *recon.at_mut(i, j) += l * mu_x[i] * mu_d[j];
            }
        }
        assert!(rel_error(&recon, &exact) < 1e-4);
    }

    #[test]
    fn quantized_wgrad_error_bounded_on_biased_data() {
        // NOTE (documented deviation, see EXPERIMENTS.md): in the wgrad GeMM
        // the reduction axis is the token axis, so blockwise scales never mix
        // feature columns and plain quantization suffers no outlier-column
        // scale pollution. Averis wgrad (Eq. 10) therefore does not *beat*
        // plain here — its μ̄ᵀμ̄ term carries a coherent quantized-mean error
        // scaled by l — it only needs to stay accurate and consistent with
        // the split already used in fwd/dgrad. The paper's own App. D
        // reports the backward centering gain as marginal (13.6% → 13.5%).
        let mut rng = Rng::new(56);
        let x = mean_biased(128, 64, 3.0, 0.4, &mut rng);
        let d = mean_biased(128, 48, 1.0, 0.3, &mut rng);
        let exact = x.matmul_at(&d);
        let q = Nvfp4Quantizer::nvfp4();
        let qsr = Nvfp4Quantizer::new(super::super::nvfp4::Nvfp4Config::nvfp4_sr());
        let mut rng2 = Rng::new(57);
        let averis = averis_wgrad(&x, &d, &q, &qsr, &mut rng2);
        let ea = rel_error(&averis, &exact);
        assert!(ea < 0.15, "averis wgrad err {ea} should stay small");
        // and the exact (unquantized) Eq.-10 identity is already covered by
        // wgrad_cross_terms_vanish above
    }

    #[test]
    fn dgrad_shape_and_sanity() {
        let mut rng = Rng::new(58);
        let d = mean_biased(32, 24, 1.0, 0.5, &mut rng);
        let w = Mat::randn(16, 24, 0.2, &mut rng);
        let q = Nvfp4Quantizer::nvfp4();
        let qsr = Nvfp4Quantizer::new(super::super::nvfp4::Nvfp4Config::nvfp4_sr());
        let mut r = Rng::new(59);
        let dx = averis_dgrad(&d, &w, &qsr, &q, &mut r);
        assert_eq!((dx.rows, dx.cols), (32, 16));
        let exact = d.matmul_bt(&w);
        assert!(rel_error(&dx, &exact) < 0.2, "err {}", rel_error(&dx, &exact));
    }
}
