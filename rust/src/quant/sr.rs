//! Counter-based stochastic-rounding streams.
//!
//! The training engine used to thread one shared sequential `&mut Rng`
//! through every stochastically rounded quantization, which serializes the
//! backward quantize passes and makes the random stream depend on row
//! visit order. Here each SR quantization call mints one [`SrTicket`] from
//! the engine's [`SrStream`] (a per-engine key plus a call counter), and
//! each row block derives its own lane RNG from the ticket. The bits a
//! block consumes are a pure function of `(key, call, row)`, so quantize
//! passes parallelize freely and the same seed produces the same training
//! curve at any thread count.

use crate::tensor::Rng;

/// One SR quantization call's worth of randomness: hands out an independent,
/// deterministic RNG per row lane.
#[derive(Clone, Copy, Debug)]
pub struct SrTicket {
    key: u64,
    ctr: u64,
}

impl SrTicket {
    /// Construct a ticket directly (tests / standalone callers). Engine code
    /// should mint tickets from an [`SrStream`] instead.
    pub fn new(key: u64, ctr: u64) -> SrTicket {
        SrTicket { key, ctr }
    }

    /// The RNG for one row lane of this call.
    pub fn lane_rng(self, lane: u64) -> Rng {
        Rng::counter_seeded(self.key, self.ctr, lane)
    }
}

/// A per-engine ticket mint: a fixed key and a monotone call counter.
/// Advanced only on the orchestrating thread, so the ticket sequence —
/// and therefore every SR bit — is independent of worker scheduling.
#[derive(Clone, Debug)]
pub struct SrStream {
    key: u64,
    ctr: u64,
}

impl SrStream {
    pub fn new(key: u64) -> SrStream {
        SrStream { key, ctr: 0 }
    }

    /// Mint the ticket for the next SR quantization call.
    pub fn ticket(&mut self) -> SrTicket {
        self.ctr += 1;
        SrTicket { key: self.key, ctr: self.ctr }
    }

    /// The number of tickets minted so far — the stream's resume cursor.
    pub fn cursor(&self) -> u64 {
        self.ctr
    }

    /// Rewind/advance the mint to an exact cursor (checkpoint resume). The
    /// key stays: a stream restored at `cursor()` mints the same tickets an
    /// uninterrupted stream would have.
    pub fn set_cursor(&mut self, ctr: u64) {
        self.ctr = ctr;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tickets_advance_and_replay() {
        let mut s1 = SrStream::new(42);
        let mut s2 = SrStream::new(42);
        let a1 = s1.ticket().lane_rng(0).next_u64();
        let a2 = s2.ticket().lane_rng(0).next_u64();
        assert_eq!(a1, a2, "same stream position must replay identically");
        let b1 = s1.ticket().lane_rng(0).next_u64();
        assert_ne!(a1, b1, "successive tickets must differ");
    }

    #[test]
    fn cursor_restore_resumes_the_ticket_sequence() {
        let mut live = SrStream::new(9);
        let _ = live.ticket();
        let _ = live.ticket();
        let mut resumed = SrStream::new(9);
        resumed.set_cursor(live.cursor());
        assert_eq!(
            live.ticket().lane_rng(3).next_u64(),
            resumed.ticket().lane_rng(3).next_u64()
        );
    }

    #[test]
    fn lanes_are_independent() {
        let t = SrTicket::new(7, 1);
        assert_ne!(t.lane_rng(0).next_u64(), t.lane_rng(1).next_u64());
    }
}
