//! FP8 scale codecs: E4M3 (OCP "fn" variant, max 448), E5M2, and E8M0
//! (power-of-two scales, used by MXFP4 block scaling).
//!
//! These are used for *block scales*, not elements: NVFP4 stores one E4M3
//! scale per 16-element block, MXFP4 one E8M0 scale per 32-element block.

/// Largest finite E4M3 value (S.1111.110 = 448).
pub const E4M3_MAX: f32 = 448.0;

/// Largest finite E5M2 value (57344).
pub const E5M2_MAX: f32 = 57344.0;

/// Quantize f32 → nearest representable E4M3 value (round-to-nearest-even),
/// saturating to ±448. Subnormals (2^-9 granularity below 2^-6) included.
///
/// Hot path (called once per 16-element block by the NVFP4 quantizer): a
/// bit-twiddling mantissa rounding replaces the original log2/powi form
/// (§Perf iteration 2; differentially tested against `e4m3_quantize_ref`).
#[inline]
pub fn e4m3_quantize(x: f32) -> f32 {
    if x.is_nan() {
        return 0.0;
    }
    let sign = if x.is_sign_negative() { -1.0f32 } else { 1.0 };
    let mag = x.abs();
    if mag == 0.0 {
        return 0.0;
    }
    if mag >= E4M3_MAX {
        return sign * E4M3_MAX;
    }
    const MIN_NORMAL: f32 = 0.015625; // 2^-6
    if mag < MIN_NORMAL {
        // subnormal: fixed quantum 2^-9
        const Q: f32 = 512.0; // 1/2^-9
        return sign * (mag * Q).round_ties_even() * (1.0 / Q);
    }
    // normal: round the f32 mantissa to 3 bits (RTNE) by integer arithmetic
    let bits = mag.to_bits();
    const DROP: u32 = 23 - 3;
    let lsb = (bits >> DROP) & 1;
    let rounded = bits
        .wrapping_add(lsb)
        .wrapping_add((1u32 << (DROP - 1)) - 1)
        & !((1u32 << DROP) - 1);
    let q = f32::from_bits(rounded);
    sign * q.min(E4M3_MAX)
}

/// Reference implementation (generic small-float path) kept for
/// differential testing.
pub fn e4m3_quantize_ref(x: f32) -> f32 {
    quantize_fp(x, 4, 3, 7, E4M3_MAX)
}

/// Quantize f32 → nearest representable E5M2 value, saturating.
pub fn e5m2_quantize(x: f32) -> f32 {
    quantize_fp(x, 5, 2, 15, E5M2_MAX)
}

/// Quantize a positive scale to E8M0: the nearest power of two, exponent in
/// [-127, 127]. By MX convention scales round *up* to the next power of two
/// so that elements never overflow after scaling.
pub fn e8m0_quantize(x: f32) -> f32 {
    if x <= 0.0 || !x.is_finite() {
        return 2f32.powi(-127);
    }
    let e = x.log2().ceil() as i32;
    2f32.powi(e.clamp(-127, 127))
}

/// Generic small-float RTNE quantizer: `ebits` exponent bits, `mbits`
/// mantissa bits, bias `bias`, saturating at ±`max`.
fn quantize_fp(x: f32, _ebits: u32, mbits: u32, bias: i32, max: f32) -> f32 {
    if x.is_nan() {
        return 0.0; // scales are never NaN in our pipeline; clamp defensively
    }
    let sign = if x.is_sign_negative() { -1.0f32 } else { 1.0 };
    let mag = x.abs();
    if mag == 0.0 {
        return 0.0;
    }
    if mag >= max {
        return sign * max;
    }
    // exponent of the value
    let mut e = mag.log2().floor() as i32;
    let emin = 1 - bias; // minimum normal exponent
    if e < emin {
        e = emin; // subnormal range: fixed scale 2^emin with mbits fraction
    }
    // quantum at this exponent
    let quantum = 2f32.powi(e - mbits as i32);
    let q = (mag / quantum).round_ties_even() * quantum;
    // rounding may push into the next binade; that's fine (value is exact)
    sign * q.min(max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn e4m3_exact_values() {
        // representable values round-trip
        for &v in &[1.0f32, 1.125, 0.5, 448.0, 208.0, 0.001953125 /* 2^-9, min subnormal */] {
            assert_eq!(e4m3_quantize(v), v, "{v}");
        }
    }

    #[test]
    fn e4m3_saturates() {
        assert_eq!(e4m3_quantize(500.0), 448.0);
        assert_eq!(e4m3_quantize(-1e9), -448.0);
    }

    #[test]
    fn e4m3_rounds_to_grid() {
        // between 1.0 and 1.125, closer to 1.0
        assert_eq!(e4m3_quantize(1.05), 1.0);
        // 3-bit mantissa at exponent 8: quantum 32 in [256,448]
        assert_eq!(e4m3_quantize(300.0), 288.0);
    }

    #[test]
    fn e4m3_relative_error_bound() {
        // normal range relative error ≤ 2^-4 = 6.25%
        let mut x = 0.02f32;
        while x < 440.0 {
            let q = e4m3_quantize(x);
            assert!(((q - x) / x).abs() <= 0.0625 + 1e-6, "x={x} q={q}");
            x *= 1.37;
        }
    }

    #[test]
    fn bit_twiddled_matches_reference() {
        // dense sweep over the whole E4M3 range, both rounding regions
        let mut x = 1e-4f32;
        while x < 500.0 {
            assert_eq!(e4m3_quantize(x), e4m3_quantize_ref(x), "x={x}");
            assert_eq!(e4m3_quantize(-x), e4m3_quantize_ref(-x), "-x={x}");
            x *= 1.009;
        }
        // exact powers of two and halfway points
        for e in -9..9 {
            let v = 2f32.powi(e);
            assert_eq!(e4m3_quantize(v), e4m3_quantize_ref(v), "2^{e}");
            let mid = v * (1.0 + 1.0 / 16.0);
            assert_eq!(e4m3_quantize(mid), e4m3_quantize_ref(mid), "mid 2^{e}");
        }
    }

    #[test]
    fn e5m2_basics() {
        assert_eq!(e5m2_quantize(1.0), 1.0);
        assert_eq!(e5m2_quantize(6.0), 6.0);
        assert_eq!(e5m2_quantize(1e9), E5M2_MAX);
    }

    #[test]
    fn e8m0_powers_of_two() {
        assert_eq!(e8m0_quantize(1.0), 1.0);
        assert_eq!(e8m0_quantize(2.0), 2.0);
        assert_eq!(e8m0_quantize(0.25), 0.25);
        // rounds UP so elements can't overflow
        assert_eq!(e8m0_quantize(1.1), 2.0);
        assert_eq!(e8m0_quantize(3.9), 4.0);
    }
}
