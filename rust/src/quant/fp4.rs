//! E2M1 ("FP4") element codec.
//!
//! Layout: 1 sign bit, 2 exponent bits, 1 mantissa bit. Representable
//! magnitudes: {0, 0.5, 1, 1.5, 2, 3, 4, 6}. This is the element format of
//! both NVFP4 and MXFP4.
//!
//! The hot path never branches per element: round-to-nearest-even over the
//! 8-point grid is a straight threshold ladder, and encode/decode use LUTs.

use crate::tensor::Rng;

/// The non-negative E2M1 grid in code order (code 0..=7).
pub const E2M1_VALUES: [f32; 8] = [0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0];

/// The full signed grid by 4-bit code (bit3 = sign). Code 8 is **-0.0**:
/// the fused fake-quant path produces -0.0 for negative values that round
/// to zero magnitude, the packed store keeps its sign bit, and decode must
/// reproduce the sign bit for bit.
///
/// Structural invariant the AVX2 decode kernel relies on (DESIGN.md §9):
/// `E2M1_SIGNED_VALUES[code]` is exactly `E2M1_VALUES[code & 7]` with
/// code bit 3 moved into f32 bit 31 — the magnitude table indexed by the
/// low bits plus a sign-bit XOR. Pinned by
/// `signed_grid_is_magnitude_table_plus_sign_bit` below; a change here
/// that silently broke it would desynchronize the in-register permute
/// decode from the LUT path.
pub const E2M1_SIGNED_VALUES: [f32; 16] = [
    0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0, -0.0, -0.5, -1.0, -1.5, -2.0, -3.0, -4.0, -6.0,
];

/// Byte-pair decode LUT: `E2M1_BYTE_PAIR_LUT[byte] = [lo, hi]`, the decoded
/// values of the byte's lo nibble (even column) and hi nibble (odd column).
/// One table lookup emits two elements, replacing the per-nibble
/// shift/mask/match of the v1 decode loop (`decode_row_range_nibble` in
/// `quant::nvfp4` keeps the old form as the differential baseline). The
/// table is 2 KiB — resident in L1 for the whole GEMM.
pub const E2M1_BYTE_PAIR_LUT: [[f32; 2]; 256] = build_byte_pair_lut();

const fn build_byte_pair_lut() -> [[f32; 2]; 256] {
    let mut lut = [[0.0f32; 2]; 256];
    let mut byte = 0usize;
    while byte < 256 {
        lut[byte] = [E2M1_SIGNED_VALUES[byte & 0xF], E2M1_SIGNED_VALUES[byte >> 4]];
        byte += 1;
    }
    lut
}

/// Largest representable magnitude.
pub const E2M1_MAX: f32 = 6.0;

/// Midpoints between adjacent grid values; used for RTNE thresholds.
/// Ties (exact midpoints) round to the value with even mantissa, matching
/// IEEE round-to-nearest-even applied on the 4-bit grid:
///   0.25→0.0(even), 0.75→1.0, 1.25→1.5→(1.5 has odd mantissa; even neighbor
///   is 1.0)… — we follow the hardware convention of rounding half-to-even in
///   *code space*: codes with LSB 0 are "even".
const MIDPOINTS: [f32; 7] = [0.25, 0.75, 1.25, 1.75, 2.5, 3.5, 5.0];

/// Quantize a magnitude-scaled value to the nearest E2M1 code (0..=7), RTNE.
/// `x` must be non-negative. (Reference ladder; the hot path uses the
/// branchless segment form in `e2m1_quantize` — see §Perf in EXPERIMENTS.md.)
#[inline]
fn nearest_code(x: f32) -> u8 {
    let mut c = 0u8;
    for (i, &m) in MIDPOINTS.iter().enumerate() {
        if x > m {
            c = i as u8 + 1;
        } else if x == m {
            // tie: round half to even code
            let lo = i as u8;
            let hi = i as u8 + 1;
            c = if lo & 1 == 0 { lo } else { hi };
            return c;
        }
    }
    c
}

/// Round a real value to the E2M1 grid (round-to-nearest, ties-to-even-code),
/// saturating at ±6.
///
/// Branchless segment form: the grid is uniform with step 0.5 on [0,2),
/// 1 on [2,4) and 2 on [4,6]; `round_ties_even` inside each segment
/// reproduces ties-to-even-code exactly (pinned by unit tests and by the
/// python contract in kernels/ref.py). ~4x faster than the threshold ladder
/// on the fused quantizer hot path.
#[inline]
pub fn e2m1_quantize(x: f32) -> f32 {
    let mag = x.abs().min(E2M1_MAX);
    let lo = (mag * 2.0).round_ties_even() * 0.5;
    let mid = mag.round_ties_even();
    let hi = (mag * 0.5).round_ties_even() * 2.0;
    let v = if mag < 1.75 {
        lo
    } else if mag < 3.5 {
        mid
    } else {
        hi
    };
    if x.is_sign_negative() {
        -v
    } else {
        v
    }
}

/// Reference (ladder) implementation kept for differential testing.
#[inline]
pub fn e2m1_quantize_ladder(x: f32) -> f32 {
    let mag = x.abs().min(E2M1_MAX);
    let v = E2M1_VALUES[nearest_code(mag) as usize];
    if x.is_sign_negative() {
        -v
    } else {
        v
    }
}

/// Stochastic rounding to the E2M1 grid: round to one of the two bracketing
/// grid points with probability proportional to proximity. Unbiased:
/// E[sr(x)] = clamp(x). Used for backward-GeMM operands per the paper.
#[inline]
pub fn e2m1_quantize_sr(x: f32, rng: &mut Rng) -> f32 {
    let neg = x.is_sign_negative();
    let mag = x.abs();
    if mag >= E2M1_MAX {
        return if neg { -E2M1_MAX } else { E2M1_MAX };
    }
    // find bracketing grid points
    let mut hi_idx = 1;
    while E2M1_VALUES[hi_idx] < mag {
        hi_idx += 1;
    }
    let lo = E2M1_VALUES[hi_idx - 1];
    let hi = E2M1_VALUES[hi_idx];
    let p_hi = (mag - lo) / (hi - lo);
    let v = if rng.uniform() < p_hi { hi } else { lo };
    if neg {
        -v
    } else {
        v
    }
}

/// Encode a (pre-rounded) E2M1 value to its 4-bit code: bit3 = sign,
/// bits2..0 = magnitude code.
#[inline]
pub fn e2m1_encode(v: f32) -> u8 {
    let sign = if v.is_sign_negative() { 8u8 } else { 0u8 };
    let mag = v.abs();
    // exact match against the grid (values are exact in f32)
    let code = E2M1_VALUES
        .iter()
        .position(|&g| g == mag)
        .expect("e2m1_encode: value not on grid") as u8;
    sign | code
}

/// Decode a 4-bit E2M1 code to f32.
#[inline]
pub fn e2m1_decode(code: u8) -> f32 {
    let v = E2M1_VALUES[(code & 7) as usize];
    if code & 8 != 0 {
        -v
    } else {
        v
    }
}

/// Pack two 4-bit codes into one byte (lo nibble = first element).
#[inline]
pub fn pack_nibbles(a: u8, b: u8) -> u8 {
    (a & 0xF) | (b << 4)
}

/// Unpack a byte into two 4-bit codes.
#[inline]
pub fn unpack_nibbles(byte: u8) -> (u8, u8) {
    (byte & 0xF, byte >> 4)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_points_are_fixed() {
        for &v in &E2M1_VALUES {
            assert_eq!(e2m1_quantize(v), v);
            assert_eq!(e2m1_quantize(-v), -v);
        }
    }

    #[test]
    fn saturation() {
        assert_eq!(e2m1_quantize(100.0), 6.0);
        assert_eq!(e2m1_quantize(-7.0), -6.0);
        assert_eq!(e2m1_quantize(f32::INFINITY), 6.0);
    }

    #[test]
    fn rounding_nearest() {
        assert_eq!(e2m1_quantize(0.3), 0.5);
        assert_eq!(e2m1_quantize(0.2), 0.0);
        assert_eq!(e2m1_quantize(1.1), 1.0);
        assert_eq!(e2m1_quantize(1.4), 1.5);
        assert_eq!(e2m1_quantize(2.6), 3.0);
        assert_eq!(e2m1_quantize(4.9), 4.0);
        assert_eq!(e2m1_quantize(5.1), 6.0);
        assert_eq!(e2m1_quantize(-2.4), -2.0);
    }

    #[test]
    fn ties_round_to_even_code() {
        // 0.25 between codes 0 (0.0, even) and 1 (0.5, odd) → 0.0
        assert_eq!(e2m1_quantize(0.25), 0.0);
        // 0.75 between codes 1 (0.5, odd) and 2 (1.0, even) → 1.0
        assert_eq!(e2m1_quantize(0.75), 1.0);
        // 2.5 between codes 4 (2.0, even) and 5 (3.0, odd) → 2.0
        assert_eq!(e2m1_quantize(2.5), 2.0);
        // 5.0 is itself a midpoint between 4.0 (code 6, even) and 6.0 (code 7) → 4.0
        assert_eq!(e2m1_quantize(5.0), 4.0);
    }

    #[test]
    fn encode_decode_roundtrip() {
        for code in 0u8..16 {
            let v = e2m1_decode(code);
            // -0.0 encodes back to 8, 0.0 to 0; both decode to 0.0
            assert_eq!(e2m1_decode(e2m1_encode(v)).abs(), v.abs());
        }
    }

    #[test]
    fn nibble_pack_roundtrip() {
        for a in 0u8..16 {
            for b in 0u8..16 {
                assert_eq!(unpack_nibbles(pack_nibbles(a, b)), (a, b));
            }
        }
    }

    #[test]
    fn stochastic_rounding_is_unbiased() {
        let mut rng = Rng::new(77);
        for &x in &[0.3f32, 1.2, 2.7, -4.5, 5.5, 0.05] {
            let n = 40_000;
            let mean: f64 = (0..n).map(|_| e2m1_quantize_sr(x, &mut rng) as f64).sum::<f64>()
                / n as f64;
            assert!(
                (mean - x as f64).abs() < 0.02,
                "SR biased at {x}: mean {mean}"
            );
        }
    }

    #[test]
    fn branchless_matches_ladder_reference() {
        // differential test across a dense sweep including all midpoints
        let mut x = -7.0f32;
        while x <= 7.0 {
            assert_eq!(
                e2m1_quantize(x),
                e2m1_quantize_ladder(x),
                "mismatch at {x}"
            );
            x += 0.015625; // 1/64 steps hit every midpoint exactly
        }
    }

    #[test]
    fn byte_pair_lut_matches_scalar_decode_bitwise() {
        // every byte, both nibbles, including the -0.0 codes (sign bit must
        // survive: -0.0 and +0.0 compare equal but differ in bits)
        for byte in 0usize..256 {
            let [lo, hi] = E2M1_BYTE_PAIR_LUT[byte];
            assert_eq!(
                lo.to_bits(),
                e2m1_decode((byte & 0xF) as u8).to_bits(),
                "lo nibble of byte {byte:#04x}"
            );
            assert_eq!(
                hi.to_bits(),
                e2m1_decode((byte >> 4) as u8).to_bits(),
                "hi nibble of byte {byte:#04x}"
            );
        }
        // spot-check the negative-zero code explicitly
        assert_eq!(E2M1_SIGNED_VALUES[8].to_bits(), (-0.0f32).to_bits());
    }

    #[test]
    fn signed_grid_is_magnitude_table_plus_sign_bit() {
        // the decomposition the AVX2 decode kernel performs in registers
        // (magnitude permute over E2M1_VALUES, sign from code bit 3):
        // it must agree with the signed table for all 16 codes, bitwise
        for code in 0u32..16 {
            let composed =
                E2M1_VALUES[(code & 7) as usize].to_bits() ^ ((code & 8) << 28);
            assert_eq!(
                E2M1_SIGNED_VALUES[code as usize].to_bits(),
                composed,
                "code {code}"
            );
        }
    }

    #[test]
    fn stochastic_rounding_saturates() {
        let mut rng = Rng::new(5);
        assert_eq!(e2m1_quantize_sr(9.0, &mut rng), 6.0);
        assert_eq!(e2m1_quantize_sr(-9.0, &mut rng), -6.0);
    }
}
