//! Downstream probe tasks — the Table-1 downstream-evaluation stand-ins
//! (see DESIGN.md §3: ARC/RACE/… are unavailable offline; these probes
//! measure the same quantity — task accuracy of the trained model under an
//! NVFP4-quantized forward pass — on tasks the synthetic corpus makes
//! learnable).
//!
//!  * `Cloze`      — predict the masked last token of a frequent local bigram
//!                   context (n-gram knowledge; LAMBADA-like protocol).
//!  * `Copy`       — after seeing a span twice, predict its continuation
//!                   (exact long-range recall).
//!  * `Induction`  — after `A B … A`, predict `B` (induction-head probe;
//!                   the mechanism behind in-context cloze tasks).

use super::corpus::Corpus;
use crate::tensor::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ProbeTask {
    Cloze,
    Copy,
    Induction,
}

impl ProbeTask {
    pub fn name(self) -> &'static str {
        match self {
            ProbeTask::Cloze => "Cloze",
            ProbeTask::Copy => "Copy",
            ProbeTask::Induction => "Induction",
        }
    }

    pub const ALL: [ProbeTask; 3] = [ProbeTask::Cloze, ProbeTask::Copy, ProbeTask::Induction];
}

/// One probe instance: a context and the expected next token.
#[derive(Clone, Debug)]
pub struct ProbeExample {
    pub context: Vec<u32>,
    pub answer: u32,
}

/// A set of probe examples per task, drawn from the held-out split.
pub struct ProbeSet {
    pub task: ProbeTask,
    pub examples: Vec<ProbeExample>,
}

impl ProbeSet {
    /// Build `n` examples of `task` with contexts of length `ctx_len` from
    /// the held-out stream.
    pub fn build(corpus: &Corpus, task: ProbeTask, ctx_len: usize, n: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let held = &corpus.heldout;
        let mut examples = Vec::with_capacity(n);
        let mut guard = 0usize;
        while examples.len() < n && guard < n * 200 {
            guard += 1;
            match task {
                ProbeTask::Cloze => {
                    // natural continuation: any held-out position; answer is
                    // the true next token
                    let pos = ctx_len + rng.below(held.len() - ctx_len - 1);
                    examples.push(ProbeExample {
                        context: held[pos - ctx_len..pos].to_vec(),
                        answer: held[pos],
                    });
                }
                ProbeTask::Copy => {
                    // synthesize: [prefix | span | span-prefix] → next span tok
                    let span_len = 6usize.min(ctx_len / 3);
                    let prefix_len = ctx_len - 2 * span_len;
                    let p0 = rng.below(held.len() - ctx_len - 2);
                    let mut ctx = held[p0..p0 + prefix_len].to_vec();
                    let span: Vec<u32> =
                        (0..span_len).map(|k| held[(p0 + prefix_len + k) % held.len()]).collect();
                    ctx.extend_from_slice(&span);
                    ctx.extend_from_slice(&span[..span_len - 1]);
                    let answer = span[span_len - 1];
                    examples.push(ProbeExample { context: ctx, answer });
                }
                ProbeTask::Induction => {
                    // [noise | A B | noise | A] → B, with A a cue token that
                    // does not occur elsewhere in the context (well-posed)
                    let p0 = rng.below(held.len() - ctx_len - 2);
                    let mut ctx = held[p0..p0 + ctx_len - 3].to_vec();
                    let mut a = held[rng.below(held.len())];
                    let mut tries = 0;
                    while ctx.contains(&a) && tries < 50 {
                        a = held[rng.below(held.len())];
                        tries += 1;
                    }
                    if ctx.contains(&a) {
                        continue; // could not find a clean cue; resample
                    }
                    let b = held[rng.below(held.len())];
                    let mid = ctx.len() / 2;
                    ctx[mid] = a;
                    ctx[mid + 1] = b;
                    ctx.push(a);
                    examples.push(ProbeExample { context: ctx, answer: b });
                }
            }
        }
        ProbeSet { task, examples }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::CorpusConfig;

    fn corpus() -> Corpus {
        Corpus::generate(CorpusConfig { tokens: 1 << 14, ..Default::default() }, 9)
    }

    #[test]
    fn builds_requested_count() {
        let c = corpus();
        for task in ProbeTask::ALL {
            let p = ProbeSet::build(&c, task, 24, 50, 1);
            assert_eq!(p.examples.len(), 50, "{}", task.name());
        }
    }

    #[test]
    fn contexts_have_requested_length() {
        let c = corpus();
        let p = ProbeSet::build(&c, ProbeTask::Cloze, 24, 10, 2);
        assert!(p.examples.iter().all(|e| e.context.len() == 24));
        let p = ProbeSet::build(&c, ProbeTask::Induction, 24, 10, 2);
        // induction contexts: ctx_len-3 noise + pushed A = ctx_len-2
        assert!(p.examples.iter().all(|e| e.context.len() == 24 - 2));
    }

    #[test]
    fn induction_answer_follows_cue() {
        let c = corpus();
        let p = ProbeSet::build(&c, ProbeTask::Induction, 20, 20, 3);
        for e in &p.examples {
            let a = *e.context.last().unwrap();
            // find earlier A; next token must be the answer
            let mid = e.context.iter().position(|&t| t == a).unwrap();
            assert_eq!(e.context[mid + 1], e.answer);
        }
    }

    #[test]
    fn deterministic() {
        let c = corpus();
        let p1 = ProbeSet::build(&c, ProbeTask::Copy, 24, 5, 4);
        let p2 = ProbeSet::build(&c, ProbeTask::Copy, 24, 5, 4);
        for (a, b) in p1.examples.iter().zip(p2.examples.iter()) {
            assert_eq!(a.context, b.context);
            assert_eq!(a.answer, b.answer);
        }
    }
}
