//! Synthetic-corpus substrate (DCLM stand-in, see DESIGN.md §3).
//!
//! The generator produces a structured token language with the statistical
//! properties that make LLM pretraining loss curves informative:
//!  * Zipfian unigram distribution (natural-language frequency law),
//!  * a latent topic/state Markov chain (local n-gram predictability),
//!  * long-range copy/induction episodes (the signal induction heads learn),
//! plus a deterministic held-out split and downstream probe tasks
//! (cloze / copy / induction) used as the Table-1 downstream stand-ins.

pub mod batcher;
pub mod corpus;
pub mod probes;

pub use batcher::Batcher;
pub use corpus::{Corpus, CorpusConfig};
pub use probes::{ProbeSet, ProbeTask};
