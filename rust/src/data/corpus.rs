//! Synthetic structured corpus generator.

use crate::tensor::rng::zipf_cdf;
use crate::tensor::Rng;

#[derive(Clone, Copy, Debug)]
pub struct CorpusConfig {
    pub vocab: usize,
    /// total tokens to generate
    pub tokens: usize,
    /// number of latent Markov states (topics)
    pub states: usize,
    /// probability of staying in the current state
    pub stickiness: f32,
    /// probability of opening a copy episode at any position
    pub copy_rate: f32,
    /// copy episode span length
    pub copy_len: usize,
    /// Zipf exponent for the per-state unigram distributions
    pub zipf_s: f64,
}

impl Default for CorpusConfig {
    fn default() -> Self {
        CorpusConfig {
            vocab: 256,
            tokens: 1 << 18,
            states: 8,
            stickiness: 0.95,
            copy_rate: 0.02,
            copy_len: 8,
            zipf_s: 1.1,
        }
    }
}

/// A generated corpus with a train/held-out split.
pub struct Corpus {
    pub cfg: CorpusConfig,
    pub train: Vec<u32>,
    pub heldout: Vec<u32>,
}

impl Corpus {
    /// Generate deterministically from a seed. 90/10 train/held-out split.
    pub fn generate(cfg: CorpusConfig, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        // per-state vocab permutation so states have distinct Zipf heads
        let cdf = zipf_cdf(cfg.vocab, cfg.zipf_s);
        let mut perms: Vec<Vec<u32>> = Vec::with_capacity(cfg.states);
        for _ in 0..cfg.states {
            let mut p: Vec<u32> = (0..cfg.vocab as u32).collect();
            // Fisher–Yates
            for i in (1..p.len()).rev() {
                let j = rng.below(i + 1);
                p.swap(i, j);
            }
            perms.push(p);
        }
        let mut tokens = Vec::with_capacity(cfg.tokens);
        let mut state = 0usize;
        let mut i = 0usize;
        while i < cfg.tokens {
            // state transition
            if rng.uniform() > cfg.stickiness {
                state = rng.below(cfg.states);
            }
            // copy episode: emit a sentinel, then replay a recent span —
            // learnable long-range structure (induction-head food)
            if tokens.len() > 4 * cfg.copy_len && rng.uniform() < cfg.copy_rate {
                let span = cfg.copy_len.min(cfg.tokens - i);
                let start = tokens.len() - 2 * cfg.copy_len;
                for k in 0..span {
                    let t: u32 = tokens[start + k];
                    tokens.push(t);
                    i += 1;
                    if i >= cfg.tokens {
                        break;
                    }
                }
                continue;
            }
            let z = rng.zipf(&cdf);
            tokens.push(perms[state][z]);
            i += 1;
        }
        let split = cfg.tokens * 9 / 10;
        let heldout = tokens.split_off(split);
        Corpus { cfg, train: tokens, heldout }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_generation() {
        let cfg = CorpusConfig { tokens: 4096, ..Default::default() };
        let a = Corpus::generate(cfg, 7);
        let b = Corpus::generate(cfg, 7);
        assert_eq!(a.train, b.train);
        assert_eq!(a.heldout, b.heldout);
    }

    #[test]
    fn split_sizes() {
        let cfg = CorpusConfig { tokens: 10_000, ..Default::default() };
        let c = Corpus::generate(cfg, 1);
        assert_eq!(c.train.len(), 9000);
        assert_eq!(c.heldout.len(), 1000);
    }

    #[test]
    fn tokens_in_vocab_range() {
        let cfg = CorpusConfig { tokens: 8192, vocab: 100, ..Default::default() };
        let c = Corpus::generate(cfg, 2);
        assert!(c.train.iter().all(|&t| (t as usize) < 100));
    }

    #[test]
    fn zipfian_head_dominates() {
        let cfg = CorpusConfig { tokens: 1 << 16, ..Default::default() };
        let c = Corpus::generate(cfg, 3);
        let mut counts = vec![0usize; cfg.vocab];
        for &t in &c.train {
            counts[t as usize] += 1;
        }
        counts.sort_unstable_by(|a, b| b.cmp(a));
        let top10: usize = counts[..10].iter().sum();
        assert!(
            top10 > c.train.len() / 5,
            "Zipf head too flat: top-10 {top10} of {}",
            c.train.len()
        );
    }

    #[test]
    fn copy_structure_present() {
        // with copy episodes, the corpus should contain repeated 6-grams far
        // more often than an iid stream would
        let cfg = CorpusConfig { tokens: 1 << 15, copy_rate: 0.05, ..Default::default() };
        let c = Corpus::generate(cfg, 4);
        let mut repeats = 0usize;
        let w = cfg.copy_len;
        for i in (2 * w)..(c.train.len() - w) {
            if c.train[i..i + w] == c.train[i - 2 * w..i - w] {
                repeats += 1;
            }
        }
        assert!(repeats > 10, "expected copy episodes, found {repeats}");
    }
}
