//! Batch iterator: cuts a token stream into (input, target) next-token
//! training batches of shape batch×seq, with deterministic shuffled offsets.

use crate::tensor::{Rng, RngState};

pub struct Batcher {
    tokens: Vec<u32>,
    pub batch: usize,
    pub seq: usize,
    rng: Rng,
}

impl Batcher {
    pub fn new(tokens: Vec<u32>, batch: usize, seq: usize, seed: u64) -> Self {
        assert!(tokens.len() > batch * (seq + 1), "corpus too small for batch shape");
        Batcher { tokens, batch, seq, rng: Rng::new(seed) }
    }

    /// Tokens consumed per batch.
    pub fn tokens_per_batch(&self) -> usize {
        self.batch * self.seq
    }

    /// Snapshot the shuffle-RNG position — the corpus cursor of a training
    /// checkpoint: it determines every future batch's row offsets.
    pub fn rng_state(&self) -> RngState {
        self.rng.state()
    }

    /// Restore the corpus cursor captured by [`Batcher::rng_state`].
    pub fn restore_rng(&mut self, state: RngState) {
        self.rng = Rng::from_state(state);
    }

    /// Next (inputs, targets), each batch·seq flat, targets shifted by one.
    pub fn next_batch(&mut self) -> (Vec<u32>, Vec<u32>) {
        let mut inputs = Vec::with_capacity(self.batch * self.seq);
        let mut targets = Vec::with_capacity(self.batch * self.seq);
        let max_start = self.tokens.len() - self.seq - 1;
        for _ in 0..self.batch {
            let start = self.rng.below(max_start);
            inputs.extend_from_slice(&self.tokens[start..start + self.seq]);
            targets.extend_from_slice(&self.tokens[start + 1..start + self.seq + 1]);
        }
        (inputs, targets)
    }

    /// Deterministic sequential eval batches covering a prefix of the stream.
    pub fn eval_batches(&self, n_batches: usize) -> Vec<(Vec<u32>, Vec<u32>)> {
        let mut out = Vec::with_capacity(n_batches);
        let stride = self.seq + 1;
        let mut pos = 0usize;
        for _ in 0..n_batches {
            let mut inputs = Vec::with_capacity(self.batch * self.seq);
            let mut targets = Vec::with_capacity(self.batch * self.seq);
            for _ in 0..self.batch {
                if pos + stride >= self.tokens.len() {
                    pos = 0;
                }
                inputs.extend_from_slice(&self.tokens[pos..pos + self.seq]);
                targets.extend_from_slice(&self.tokens[pos + 1..pos + self.seq + 1]);
                pos += stride;
            }
            out.push((inputs, targets));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_shift() {
        let tokens: Vec<u32> = (0..1000u32).collect();
        let mut b = Batcher::new(tokens, 2, 8, 1);
        let (x, y) = b.next_batch();
        assert_eq!(x.len(), 16);
        assert_eq!(y.len(), 16);
        // each row's target is input shifted by one (consecutive integers)
        for r in 0..2 {
            for t in 0..8 {
                assert_eq!(y[r * 8 + t], x[r * 8 + t] + 1);
            }
        }
    }

    #[test]
    fn deterministic_with_seed() {
        let tokens: Vec<u32> = (0..1000u32).collect();
        let mut a = Batcher::new(tokens.clone(), 2, 8, 42);
        let mut b = Batcher::new(tokens, 2, 8, 42);
        assert_eq!(a.next_batch(), b.next_batch());
    }

    #[test]
    fn rng_state_restore_resumes_the_batch_stream() {
        let tokens: Vec<u32> = (0..1000u32).collect();
        let mut live = Batcher::new(tokens.clone(), 2, 8, 7);
        let _ = live.next_batch();
        let snap = live.rng_state();
        let mut resumed = Batcher::new(tokens, 2, 8, 7);
        resumed.restore_rng(snap);
        assert_eq!(live.next_batch(), resumed.next_batch());
        assert_eq!(live.next_batch(), resumed.next_batch());
    }

    #[test]
    fn eval_batches_deterministic_and_sequential() {
        let tokens: Vec<u32> = (0..500u32).collect();
        let b = Batcher::new(tokens, 2, 8, 0);
        let e1 = b.eval_batches(3);
        let e2 = b.eval_batches(3);
        assert_eq!(e1.len(), 3);
        assert_eq!(e1[0], e2[0]);
        assert_eq!(e1[0].0[0], 0); // starts at stream head
    }
}
