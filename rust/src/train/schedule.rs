//! Learning-rate schedule: linear warmup → cosine decay to a floor
//! (the standard LLM-pretraining schedule the paper's runs use).

#[derive(Clone, Copy, Debug)]
pub struct LrSchedule {
    pub peak_lr: f32,
    pub min_lr: f32,
    pub warmup_steps: u64,
    pub total_steps: u64,
}

impl LrSchedule {
    pub fn new(peak_lr: f32, total_steps: u64) -> Self {
        LrSchedule {
            peak_lr,
            min_lr: peak_lr * 0.1,
            warmup_steps: (total_steps / 20).max(1),
            total_steps,
        }
    }

    /// LR at a given (0-indexed) step.
    pub fn lr_at(&self, step: u64) -> f32 {
        if step < self.warmup_steps {
            return self.peak_lr * (step + 1) as f32 / self.warmup_steps as f32;
        }
        if step >= self.total_steps {
            return self.min_lr;
        }
        let progress =
            (step - self.warmup_steps) as f32 / (self.total_steps - self.warmup_steps) as f32;
        let cos = 0.5 * (1.0 + (std::f32::consts::PI * progress).cos());
        self.min_lr + (self.peak_lr - self.min_lr) * cos
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_ramps_to_peak() {
        let s = LrSchedule::new(1e-3, 100);
        assert!(s.lr_at(0) < 1e-3);
        assert!((s.lr_at(s.warmup_steps) - 1e-3).abs() / 1e-3 < 0.02);
    }

    #[test]
    fn decays_to_min() {
        let s = LrSchedule::new(1e-3, 100);
        assert!((s.lr_at(99) - s.min_lr) / s.min_lr < 0.1);
        assert_eq!(s.lr_at(1000), s.min_lr);
    }

    #[test]
    fn monotone_decay_after_warmup() {
        let s = LrSchedule::new(3e-4, 200);
        let mut prev = s.lr_at(s.warmup_steps);
        for step in (s.warmup_steps + 1)..200 {
            let lr = s.lr_at(step);
            assert!(lr <= prev + 1e-9);
            prev = lr;
        }
    }
}
