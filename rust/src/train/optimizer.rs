//! AdamW with decoupled weight decay, operating on `Params` trees.

use crate::model::Params;

/// AdamW hyperparameters (paper-standard defaults for LLM pretraining).
#[derive(Clone, Copy, Debug)]
pub struct AdamWConfig {
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
}

impl Default for AdamWConfig {
    fn default() -> Self {
        AdamWConfig { beta1: 0.9, beta2: 0.95, eps: 1e-8, weight_decay: 0.1 }
    }
}

/// AdamW state: first/second moments shaped like the parameters.
pub struct AdamW {
    pub cfg: AdamWConfig,
    m: Params,
    v: Params,
    pub step: u64,
}

impl AdamW {
    pub fn new(params: &Params, cfg: AdamWConfig) -> Self {
        AdamW { cfg, m: params.zeros_like(), v: params.zeros_like(), step: 0 }
    }

    /// Rebuild an optimizer at an exact position (checkpoint resume): the
    /// moment trees and step counter come from a serialized snapshot.
    /// Resuming `from_parts(cfg, m, v, step)` continues bit-for-bit where
    /// the checkpointed optimizer would have.
    pub fn from_parts(cfg: AdamWConfig, m: Params, v: Params, step: u64) -> Self {
        AdamW { cfg, m, v, step }
    }

    /// The first/second moment trees (checkpoint serialization).
    pub fn moments(&self) -> (&Params, &Params) {
        (&self.m, &self.v)
    }

    /// One update: params ← params − lr·(m̂/(√v̂+ε) + wd·params).
    pub fn update(&mut self, params: &mut Params, grads: &mut Params, lr: f32) {
        self.step += 1;
        let t = self.step as f32;
        let (b1, b2) = (self.cfg.beta1, self.cfg.beta2);
        let bc1 = 1.0 - b1.powf(t);
        let bc2 = 1.0 - b2.powf(t);
        let eps = self.cfg.eps;
        let wd = self.cfg.weight_decay;

        // walk (param, grad) and (m, v) in lock-step via the deterministic
        // tree ordering
        let mut m_slices: Vec<*mut [f32]> = Vec::new();
        self.m.for_each_mut(|s| m_slices.push(s as *mut [f32]));
        let mut v_slices: Vec<*mut [f32]> = Vec::new();
        self.v.for_each_mut(|s| v_slices.push(s as *mut [f32]));
        let mut i = 0usize;
        params.zip_for_each_mut(grads, |p, g| {
            // SAFETY: each slice pointer is visited exactly once per update;
            // m/v are owned by self and disjoint from params/grads.
            let m = unsafe { &mut *m_slices[i] };
            let v = unsafe { &mut *v_slices[i] };
            for j in 0..p.len() {
                let gj = g[j];
                m[j] = b1 * m[j] + (1.0 - b1) * gj;
                v[j] = b2 * v[j] + (1.0 - b2) * gj * gj;
                let mhat = m[j] / bc1;
                let vhat = v[j] / bc2;
                p[j] -= lr * (mhat / (vhat.sqrt() + eps) + wd * p[j]);
            }
            i += 1;
        });
    }
}

/// Clip a gradient tree to a global L2 norm; returns the pre-clip norm.
pub fn clip_global_norm(grads: &mut Params, max_norm: f32) -> f32 {
    let norm = grads.global_norm();
    if norm > max_norm && norm > 0.0 {
        let scale = max_norm / norm;
        grads.for_each_mut(|s| s.iter_mut().for_each(|x| *x *= scale));
    }
    norm
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{ModelConfig, Params};
    use crate::tensor::Rng;

    #[test]
    fn update_moves_params_against_gradient() {
        let cfg = ModelConfig::test_tiny(32);
        let mut p = Params::init(&cfg, &mut Rng::new(150));
        let before = p.embed.data[0];
        let mut g = p.zeros_like();
        g.embed.data[0] = 1.0; // positive gradient
        let mut opt = AdamW::new(&p, AdamWConfig { weight_decay: 0.0, ..Default::default() });
        opt.update(&mut p, &mut g, 0.01);
        assert!(p.embed.data[0] < before, "param should decrease");
    }

    #[test]
    fn weight_decay_shrinks_params_without_gradient() {
        let cfg = ModelConfig::test_tiny(32);
        let mut p = Params::init(&cfg, &mut Rng::new(151));
        // make a clearly positive param
        p.embed.data[5] = 1.0;
        let mut g = p.zeros_like();
        let mut opt = AdamW::new(&p, AdamWConfig { weight_decay: 0.5, ..Default::default() });
        opt.update(&mut p, &mut g, 0.1);
        assert!(p.embed.data[5] < 1.0 && p.embed.data[5] > 0.0);
    }

    #[test]
    fn clip_reduces_large_norm() {
        let cfg = ModelConfig::test_tiny(32);
        let p = Params::init(&cfg, &mut Rng::new(152));
        let mut g = p.clone(); // big "gradients"
        let pre = clip_global_norm(&mut g, 1.0);
        assert!(pre > 1.0);
        assert!((g.global_norm() - 1.0).abs() < 1e-3);
    }

    #[test]
    fn clip_noop_under_threshold() {
        let cfg = ModelConfig::test_tiny(32);
        let p = Params::init(&cfg, &mut Rng::new(153));
        let mut g = p.zeros_like();
        g.embed.data[0] = 0.5;
        let pre = clip_global_norm(&mut g, 10.0);
        assert!((pre - 0.5).abs() < 1e-6);
        assert_eq!(g.embed.data[0], 0.5);
    }

    #[test]
    fn from_parts_resumes_bitwise() {
        // train 6 steps straight vs 3 steps + snapshot + 3 resumed steps:
        // the parameter trees must agree bit for bit
        let cfg = ModelConfig::test_tiny(32);
        let grad_at = |p: &Params, k: u64| {
            let mut g = p.zeros_like();
            for (j, gd) in g.embed.data.iter_mut().enumerate() {
                *gd = ((j as f32) * 0.01 + k as f32 * 0.1).sin();
            }
            g
        };
        let mut p_full = Params::init(&cfg, &mut Rng::new(155));
        let mut opt_full = AdamW::new(&p_full, AdamWConfig::default());
        let mut p_half = p_full.clone();
        let mut opt_half = AdamW::new(&p_half, AdamWConfig::default());
        for k in 0..3u64 {
            let mut g = grad_at(&p_full, k);
            opt_full.update(&mut p_full, &mut g, 0.01);
            let mut g2 = grad_at(&p_half, k);
            opt_half.update(&mut p_half, &mut g2, 0.01);
        }
        let (m, v) = opt_half.moments();
        let mut opt_resumed = AdamW::from_parts(opt_half.cfg, m.clone(), v.clone(), opt_half.step);
        for k in 3..6u64 {
            let mut g = grad_at(&p_full, k);
            opt_full.update(&mut p_full, &mut g, 0.01);
            let mut g2 = grad_at(&p_half, k);
            opt_resumed.update(&mut p_half, &mut g2, 0.01);
        }
        let mut a: Vec<u32> = Vec::new();
        p_full.for_each(|s| a.extend(s.iter().map(|x| x.to_bits())));
        let mut b: Vec<u32> = Vec::new();
        p_half.for_each(|s| b.extend(s.iter().map(|x| x.to_bits())));
        assert_eq!(a, b);
    }

    #[test]
    fn adamw_converges_on_quadratic() {
        // minimize ||embed||² via grads = 2·embed; all entries → 0
        let cfg = ModelConfig::test_tiny(32);
        let mut p = Params::init(&cfg, &mut Rng::new(154));
        let mut opt = AdamW::new(&p, AdamWConfig { weight_decay: 0.0, ..Default::default() });
        for _ in 0..300 {
            let mut g = p.zeros_like();
            for (gd, pd) in g.embed.data.iter_mut().zip(p.embed.data.iter()) {
                *gd = 2.0 * pd;
            }
            opt.update(&mut p, &mut g, 0.01);
        }
        let max = p.embed.data.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
        assert!(max < 0.02, "embed should be ~0, max {max}");
    }
}
