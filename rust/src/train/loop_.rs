//! The simulator training loop: drives the pure-Rust Transformer with a
//! quantization recipe, AdamW, LR schedule, gradient clipping, periodic
//! held-out evaluation, and optional activation-capture checkpoints for the
//! analysis pipeline.

use super::optimizer::{clip_global_norm, AdamW, AdamWConfig};
use super::schedule::LrSchedule;
use crate::data::Batcher;
use crate::model::{ModelConfig, Params, Taps, Transformer};
use crate::quant::QuantRecipe;
use crate::tensor::Rng;
use std::time::Instant;

#[derive(Clone, Copy, Debug)]
pub struct TrainConfig {
    pub steps: u64,
    pub batch: usize,
    pub seq: usize,
    pub peak_lr: f32,
    pub grad_clip: f32,
    pub eval_every: u64,
    pub eval_batches: usize,
    pub seed: u64,
    /// capture activation taps at these steps (fractions of total, e.g. the
    /// paper's "early/late checkpoint" instrumentation)
    pub tap_steps: [bool; 2], // [early(5%), late(95%)]
    /// worker threads for the GeMM / quantize kernels (0 = available
    /// parallelism). Kernels are bit-deterministic in this knob: the same
    /// seed gives the same loss curve at any thread count.
    pub threads: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            steps: 200,
            batch: 8,
            seq: 64,
            peak_lr: 3e-3,
            grad_clip: 1.0,
            eval_every: 25,
            eval_batches: 4,
            seed: 1234,
            tap_steps: [false, false],
            threads: 0,
        }
    }
}

/// Everything a training run produces.
pub struct TrainResult {
    pub recipe: QuantRecipe,
    /// (step, train loss)
    pub loss_curve: Vec<(u64, f32)>,
    /// (step, held-out loss)
    pub eval_curve: Vec<(u64, f32)>,
    pub final_train_loss: f32,
    pub final_eval_loss: f32,
    pub params: Params,
    /// captured taps: (label, taps) — "early" at 5% of steps, "late" at 95%
    pub taps: Vec<(String, Taps)>,
    pub wall_seconds: f64,
    /// mean seconds per optimizer step (for the Table-3-style comparison)
    pub sec_per_step: f64,
}

/// Train a model from scratch with the given recipe.
pub fn train(
    model_cfg: ModelConfig,
    recipe: QuantRecipe,
    cfg: TrainConfig,
    train_tokens: Vec<u32>,
    heldout_tokens: Vec<u32>,
) -> TrainResult {
    // size the persistent worker pool once for the whole run: every GeMM,
    // quantize/pack pass, and Correct stage of every step executes on it
    // with zero per-call thread spawns
    crate::tensor::parallel::install(cfg.threads);
    let mut init_rng = Rng::new(cfg.seed); // same init across recipes
    let mut params = Params::init(&model_cfg, &mut init_rng);
    let mut model = Transformer::new(model_cfg, recipe, cfg.seed ^ 0xA5A5);
    let mut opt = AdamW::new(&params, AdamWConfig::default());
    let sched = LrSchedule::new(cfg.peak_lr, cfg.steps);
    let mut batcher = Batcher::new(train_tokens, cfg.batch, cfg.seq, cfg.seed ^ 0x77);
    let eval_batcher = Batcher::new(heldout_tokens, cfg.batch, cfg.seq, 0);
    let eval_set = eval_batcher.eval_batches(cfg.eval_batches);

    let early_step = (cfg.steps / 20).max(1);
    let late_step = cfg.steps.saturating_sub(cfg.steps / 20).max(early_step + 1);

    let mut loss_curve = Vec::new();
    let mut eval_curve = Vec::new();
    let mut captured: Vec<(String, Taps)> = Vec::new();
    let t0 = Instant::now();
    let mut ema: Option<f32> = None;

    for step in 0..cfg.steps {
        let step_span = crate::telemetry::span(crate::telemetry::Span::TrainStep);
        let (inputs, targets) = batcher.next_batch();
        let capture = (cfg.tap_steps[0] && step == early_step)
            || (cfg.tap_steps[1] && step == late_step);
        let mut taps = if capture { Taps::enabled() } else { Taps::disabled() };
        let (logits, cache) = model.forward(&params, &inputs, cfg.batch, cfg.seq, &mut taps);
        let (loss, mut grads) = model.loss_and_backward(
            &params, &cache, &logits, &targets, cfg.batch, cfg.seq, &mut taps,
        );
        if capture {
            let label = if step == early_step { "early" } else { "late" };
            captured.push((label.to_string(), taps));
        }
        clip_global_norm(&mut grads, cfg.grad_clip);
        opt.update(&mut params, &mut grads, sched.lr_at(step));
        drop(step_span);
        ema = Some(match ema {
            None => loss,
            Some(e) => 0.95 * e + 0.05 * loss,
        });
        loss_curve.push((step, loss));
        if cfg.eval_every > 0 && (step + 1) % cfg.eval_every == 0 {
            let ev = evaluate(&mut model, &params, &eval_set, cfg.batch, cfg.seq);
            eval_curve.push((step, ev));
            // periodic JSONL snapshot at the eval cadence; telemetry must
            // never fail a training run, so I/O errors only warn
            if let Err(e) = crate::telemetry::write_snapshot("train", step + 1) {
                eprintln!("warning: telemetry snapshot failed: {e}");
            }
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let final_eval = evaluate(&mut model, &params, &eval_set, cfg.batch, cfg.seq);
    eval_curve.push((cfg.steps, final_eval));
    if let Err(e) = crate::telemetry::write_snapshot("train", cfg.steps) {
        eprintln!("warning: telemetry snapshot failed: {e}");
    }
    TrainResult {
        recipe,
        final_train_loss: ema.unwrap_or(f32::NAN),
        final_eval_loss: final_eval,
        loss_curve,
        eval_curve,
        params,
        taps: captured,
        wall_seconds: wall,
        sec_per_step: wall / cfg.steps.max(1) as f64,
    }
}

/// Mean held-out loss over a fixed eval set.
pub fn evaluate(
    model: &mut Transformer,
    params: &Params,
    eval_set: &[(Vec<u32>, Vec<u32>)],
    batch: usize,
    seq: usize,
) -> f32 {
    if eval_set.is_empty() {
        return f32::NAN;
    }
    let mut acc = 0.0f64;
    for (x, y) in eval_set {
        acc += model.eval_loss(params, x, y, batch, seq) as f64;
    }
    (acc / eval_set.len() as f64) as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Corpus, CorpusConfig};

    fn mini_corpus() -> Corpus {
        Corpus::generate(CorpusConfig { tokens: 1 << 14, vocab: 64, ..Default::default() }, 5)
    }

    #[test]
    fn short_bf16_run_reduces_loss() {
        let c = mini_corpus();
        let cfg = TrainConfig { steps: 30, batch: 4, seq: 16, eval_every: 0, ..Default::default() };
        let r = train(
            ModelConfig::test_tiny(64),
            QuantRecipe::Bf16,
            cfg,
            c.train.clone(),
            c.heldout.clone(),
        );
        let first = r.loss_curve.first().unwrap().1;
        let last = r.final_train_loss;
        assert!(last < first, "loss should drop: {first} → {last}");
        assert!(r.final_eval_loss.is_finite());
    }

    #[test]
    fn taps_captured_at_requested_checkpoints() {
        let c = mini_corpus();
        let cfg = TrainConfig {
            steps: 24,
            batch: 2,
            seq: 16,
            eval_every: 0,
            tap_steps: [true, true],
            ..Default::default()
        };
        let r = train(
            ModelConfig::test_tiny(64),
            QuantRecipe::Bf16,
            cfg,
            c.train.clone(),
            c.heldout.clone(),
        );
        assert_eq!(r.taps.len(), 2);
        assert_eq!(r.taps[0].0, "early");
        assert_eq!(r.taps[1].0, "late");
        assert!(!r.taps[0].1.is_empty());
    }

    #[test]
    fn same_seed_same_curve() {
        let c = mini_corpus();
        let cfg = TrainConfig { steps: 10, batch: 2, seq: 16, eval_every: 0, ..Default::default() };
        let r1 = train(
            ModelConfig::test_tiny(64),
            QuantRecipe::Nvfp4,
            cfg,
            c.train.clone(),
            c.heldout.clone(),
        );
        let r2 = train(
            ModelConfig::test_tiny(64),
            QuantRecipe::Nvfp4,
            cfg,
            c.train.clone(),
            c.heldout.clone(),
        );
        assert_eq!(r1.loss_curve, r2.loss_curve);
    }

    #[test]
    fn same_seed_same_curve_at_any_thread_count() {
        // the deterministic-parallelism contract: SR streams are
        // counter-seeded per row block and GeMM row sharding never changes
        // accumulation order, so 1, 2, and 4 workers give identical curves
        let c = mini_corpus();
        let run = |threads: usize| {
            let cfg = TrainConfig {
                steps: 8,
                batch: 2,
                seq: 16,
                eval_every: 0,
                threads,
                ..Default::default()
            };
            train(
                ModelConfig::test_tiny(64),
                QuantRecipe::Averis,
                cfg,
                c.train.clone(),
                c.heldout.clone(),
            )
        };
        let r1 = run(1);
        let r2 = run(2);
        let r4 = run(4);
        assert_eq!(r1.loss_curve, r2.loss_curve, "1 vs 2 threads");
        assert_eq!(r1.loss_curve, r4.loss_curve, "1 vs 4 threads");
    }
}
