//! The simulator training loop: drives the pure-Rust Transformer with a
//! quantization recipe, AdamW, LR schedule, gradient clipping, periodic
//! held-out evaluation, and optional activation-capture checkpoints for the
//! analysis pipeline.
//!
//! Two robustness layers ride on the plain loop (DESIGN.md §13):
//!
//! * **Crash-safe checkpointing** — at a fixed step cadence the loop writes
//!   a [`TrainSnapshot`] (params, AdamW moments, stream cursors, EMA,
//!   curves, sentinel position) atomically to disk; `--resume` restores the
//!   newest valid record and continues the loss curve bit for bit.
//! * **A numerics sentinel** — every step is checked for a non-finite loss
//!   or gradient (plus an optional loss-spike threshold). Bad steps climb a
//!   deterministic intervention ladder: skip-step (optimizer untouched) →
//!   rollback to the last on-disk record → escalate the quantization recipe
//!   (force mean-split, then the full-precision fallback). Every decision
//!   is a pure function of per-step data, so intervention sequences are
//!   identical at any thread count, and a resumed run replays them.

use super::checkpoint::{self, Intervention, InterventionKind, SentinelState, TrainSnapshot};
use super::optimizer::{clip_global_norm, AdamW, AdamWConfig};
use super::schedule::LrSchedule;
use crate::data::Batcher;
use crate::model::{ModelConfig, Params, Taps, Transformer};
use crate::quant::QuantRecipe;
use crate::serve::{FaultKind, FaultPlan};
use crate::tensor::Rng;
use anyhow::{Context, Result};
use std::path::PathBuf;
use std::time::Instant;

#[derive(Clone, Copy, Debug)]
pub struct TrainConfig {
    pub steps: u64,
    pub batch: usize,
    pub seq: usize,
    pub peak_lr: f32,
    pub grad_clip: f32,
    pub eval_every: u64,
    pub eval_batches: usize,
    pub seed: u64,
    /// capture activation taps at these steps (fractions of total, e.g. the
    /// paper's "early/late checkpoint" instrumentation)
    pub tap_steps: [bool; 2], // [early(5%), late(95%)]
    /// worker threads for the GeMM / quantize kernels (0 = available
    /// parallelism). Kernels are bit-deterministic in this knob: the same
    /// seed gives the same loss curve at any thread count.
    pub threads: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            steps: 200,
            batch: 8,
            seq: 64,
            peak_lr: 3e-3,
            grad_clip: 1.0,
            eval_every: 25,
            eval_batches: 4,
            seed: 1234,
            tap_steps: [false, false],
            threads: 0,
        }
    }
}

/// Crash-safe checkpointing knobs.
#[derive(Clone, Debug)]
pub struct CheckpointConfig {
    /// Write a train-state record every N steps (0 = disabled).
    pub every: u64,
    pub dir: Option<PathBuf>,
    /// Keep the newest K records; older ones are pruned after each write.
    pub keep: usize,
    /// Restore the newest valid record in `dir` before training.
    pub resume: bool,
}

impl Default for CheckpointConfig {
    fn default() -> Self {
        CheckpointConfig { every: 0, dir: None, keep: 3, resume: false }
    }
}

/// Numerics-sentinel knobs. The defaults leave healthy runs byte-identical
/// to a sentinel-free loop: checks only *observe* until a step goes bad.
#[derive(Clone, Copy, Debug)]
pub struct SentinelConfig {
    pub enabled: bool,
    /// Consecutive bad steps before the ladder escalates past skip-step.
    pub rollback_after: u32,
    /// Treat `loss > factor · EMA` as bad (0 = disabled). Deterministic:
    /// both operands are pure functions of the step data.
    pub loss_spike_factor: f32,
}

impl Default for SentinelConfig {
    fn default() -> Self {
        SentinelConfig { enabled: true, rollback_after: 3, loss_spike_factor: 0.0 }
    }
}

/// Everything beyond the core hyperparameters: checkpointing, the sentinel,
/// fault injection, and the in-process crash hook used by resume tests.
#[derive(Clone, Debug, Default)]
pub struct TrainOptions {
    pub checkpoint: CheckpointConfig,
    pub sentinel: SentinelConfig,
    pub faults: FaultPlan,
    /// Stop (as if killed) after executing this many steps in this process.
    /// Unlike `cfg.steps` this does not shorten the schedule — it simulates
    /// an interruption for kill-and-resume tests without a child process.
    pub halt_after_steps: Option<u64>,
}

/// What the robustness layers did during a run.
#[derive(Clone, Debug, Default)]
pub struct TrainReport {
    /// Step the run resumed from (`None` = fresh start).
    pub resumed_from: Option<u64>,
    pub checkpoints_written: u32,
    pub skipped_steps: u32,
    pub rollbacks: u32,
    pub escalations: u32,
    /// The recipe ladder ran out; remaining bad steps were skipped.
    pub ladder_dead: bool,
    pub interventions: Vec<Intervention>,
}

/// Everything a training run produces.
pub struct TrainResult {
    pub recipe: QuantRecipe,
    /// (step, train loss)
    pub loss_curve: Vec<(u64, f32)>,
    /// (step, held-out loss)
    pub eval_curve: Vec<(u64, f32)>,
    pub final_train_loss: f32,
    pub final_eval_loss: f32,
    pub params: Params,
    /// captured taps: (label, taps) — "early" at 5% of steps, "late" at 95%
    pub taps: Vec<(String, Taps)>,
    pub wall_seconds: f64,
    /// mean seconds per optimizer step (for the Table-3-style comparison)
    pub sec_per_step: f64,
    /// The recipe the run finished on (differs from `recipe` only if the
    /// sentinel escalated).
    pub final_recipe: QuantRecipe,
    pub report: TrainReport,
}

/// Train a model from scratch with the given recipe (no checkpointing, no
/// fault injection; the sentinel runs with its pure-observer defaults).
pub fn train(
    model_cfg: ModelConfig,
    recipe: QuantRecipe,
    cfg: TrainConfig,
    train_tokens: Vec<u32>,
    heldout_tokens: Vec<u32>,
) -> TrainResult {
    train_with(model_cfg, recipe, cfg, TrainOptions::default(), train_tokens, heldout_tokens)
        .expect("train without checkpointing performs no fallible I/O")
}

/// The next rung of the recipe-escalation ladder: plain FP4 recipes gain
/// mean-split (the paper's bias fix), mean-split recipes fall back to full
/// precision, and full precision has nowhere left to go.
fn next_recipe(r: QuantRecipe) -> Option<QuantRecipe> {
    match r {
        QuantRecipe::Nvfp4 | QuantRecipe::Nvfp4Hadamard | QuantRecipe::Mxfp4 => {
            Some(QuantRecipe::Averis)
        }
        QuantRecipe::Averis | QuantRecipe::AverisHadamard | QuantRecipe::SvdSplit => {
            Some(QuantRecipe::Bf16)
        }
        QuantRecipe::Bf16 => None,
    }
}

/// Train with explicit robustness options. See [`TrainOptions`].
pub fn train_with(
    model_cfg: ModelConfig,
    recipe: QuantRecipe,
    cfg: TrainConfig,
    opts: TrainOptions,
    train_tokens: Vec<u32>,
    heldout_tokens: Vec<u32>,
) -> Result<TrainResult> {
    // size the persistent worker pool once for the whole run: every GeMM,
    // quantize/pack pass, and Correct stage of every step executes on it
    // with zero per-call thread spawns
    crate::tensor::parallel::install(cfg.threads);
    let mut init_rng = Rng::new(cfg.seed); // same init across recipes
    let mut params = Params::init(&model_cfg, &mut init_rng);
    let mut model = Transformer::new(model_cfg, recipe, cfg.seed ^ 0xA5A5);
    let mut opt = AdamW::new(&params, AdamWConfig::default());
    let sched = LrSchedule::new(cfg.peak_lr, cfg.steps);
    let mut batcher = Batcher::new(train_tokens, cfg.batch, cfg.seq, cfg.seed ^ 0x77);
    let eval_batcher = Batcher::new(heldout_tokens, cfg.batch, cfg.seq, 0);
    let eval_set = eval_batcher.eval_batches(cfg.eval_batches);

    let early_step = (cfg.steps / 20).max(1);
    let late_step = cfg.steps.saturating_sub(cfg.steps / 20).max(early_step + 1);

    let mut loss_curve: Vec<(u64, f32)> = Vec::new();
    let mut eval_curve: Vec<(u64, f32)> = Vec::new();
    let mut captured: Vec<(String, Taps)> = Vec::new();
    let mut ema: Option<f32> = None;
    let mut wall_accum = 0.0f64;
    let mut active_recipe = recipe;
    let mut sentinel = SentinelState::default();
    let mut report = TrainReport::default();
    let mut start_step = 0u64;

    let ckpt_dir = opts.checkpoint.dir.clone();
    let ckpt_every = opts.checkpoint.every;

    if opts.checkpoint.resume {
        let dir = ckpt_dir
            .as_ref()
            .context("resume requested without a checkpoint dir")?;
        if let Some((_, snap)) = checkpoint::find_latest_valid(dir, &opts.faults) {
            snap.check_guard(&model_cfg, recipe, &cfg)?;
            start_step = snap.next_step;
            report.resumed_from = Some(snap.next_step);
            params = snap.params;
            opt = AdamW::from_parts(AdamWConfig::default(), snap.opt_m, snap.opt_v, snap.opt_step);
            batcher.restore_rng(snap.batcher_rng);
            active_recipe = snap.active_recipe;
            if active_recipe != recipe {
                model.gemm.set_recipe(active_recipe);
            }
            model.gemm.restore_stream_cursors(snap.sr_cursor, snap.aux_rng);
            ema = snap.ema;
            loss_curve = snap.loss_curve;
            eval_curve = snap.eval_curve;
            wall_accum = snap.wall_seconds;
            sentinel = snap.sentinel;
        }
        // nothing valid on disk → fresh start (first launch with --resume
        // in the loop, or every record lost): state above is already fresh
    }

    let halt_at = opts.halt_after_steps.map(|n| start_step.saturating_add(n));
    let t0 = Instant::now();
    let mut step = start_step;
    while step < cfg.steps {
        if let Some(h) = halt_at {
            if step >= h {
                break;
            }
        }
        let step_span = crate::telemetry::span(crate::telemetry::Span::TrainStep);
        let (inputs, targets) = batcher.next_batch();
        let capture = (cfg.tap_steps[0] && step == early_step)
            || (cfg.tap_steps[1] && step == late_step);
        let mut taps = if capture { Taps::enabled() } else { Taps::disabled() };
        let (logits, cache) = model.forward(&params, &inputs, cfg.batch, cfg.seq, &mut taps);
        let (mut loss, mut grads) = model.loss_and_backward(
            &params, &cache, &logits, &targets, cfg.batch, cfg.seq, &mut taps,
        );
        if capture {
            let label = if step == early_step { "early" } else { "late" };
            captured.push((label.to_string(), taps));
        }
        // injected numerics fault — keyed on the step index (not a shared
        // draw counter), so the injection pattern is identical under
        // resume, rollback replay, and any thread count
        if opts.faults.fire_at(FaultKind::StepNonfinite, step) {
            loss = f32::NAN;
        }
        // the sentinel reads only per-step deterministic data: the loss,
        // the pre-clip gradient norm (finite ⟺ every gradient entry — and
        // hence the grad amax — is finite), and the deterministic EMA.
        // Telemetry gauges are cumulative and stride-sampled, so they are
        // recorded but never consulted.
        let grad_norm = grads.global_norm();
        let spike = opts.sentinel.loss_spike_factor > 0.0
            && matches!(ema, Some(e) if loss > opts.sentinel.loss_spike_factor * e);
        let bad =
            opts.sentinel.enabled && (!loss.is_finite() || !grad_norm.is_finite() || spike);
        if bad {
            drop(step_span);
            sentinel.consecutive_bad += 1;
            sentinel.skipped += 1;
            report.skipped_steps += 1;
            crate::telemetry::incr(crate::telemetry::Counter::SentinelSkips, 1);
            let detail = format!("loss={loss} grad_norm={grad_norm} spike={spike}");
            sentinel.interventions.push(Intervention {
                step,
                kind: InterventionKind::SkipStep,
                detail: detail.clone(),
            });
            report.interventions.push(Intervention {
                step,
                kind: InterventionKind::SkipStep,
                detail,
            });
            if sentinel.consecutive_bad >= opts.sentinel.rollback_after.max(1)
                && !sentinel.ladder_dead
            {
                // ladder rung 0: roll back to the newest valid on-disk
                // record, if one exists. Numeric state only — the active
                // recipe and the sentinel's own bookkeeping survive, so a
                // rollback→re-diverge cycle escalates instead of looping.
                let rollback_to = if sentinel.rung == 0 {
                    ckpt_dir
                        .as_ref()
                        .and_then(|d| checkpoint::find_latest_valid(d, &opts.faults))
                        .filter(|(_, s)| s.check_guard(&model_cfg, recipe, &cfg).is_ok())
                } else {
                    None
                };
                match rollback_to {
                    Some((path, snap)) => {
                        sentinel.rollbacks += 1;
                        report.rollbacks += 1;
                        crate::telemetry::incr(
                            crate::telemetry::Counter::SentinelRollbacks,
                            1,
                        );
                        let detail =
                            format!("restored step {} from {}", snap.next_step, path.display());
                        sentinel.interventions.push(Intervention {
                            step,
                            kind: InterventionKind::Rollback,
                            detail: detail.clone(),
                        });
                        report.interventions.push(Intervention {
                            step,
                            kind: InterventionKind::Rollback,
                            detail,
                        });
                        params = snap.params;
                        opt = AdamW::from_parts(
                            AdamWConfig::default(),
                            snap.opt_m,
                            snap.opt_v,
                            snap.opt_step,
                        );
                        batcher.restore_rng(snap.batcher_rng);
                        model.gemm.restore_stream_cursors(snap.sr_cursor, snap.aux_rng);
                        ema = snap.ema;
                        loss_curve = snap.loss_curve;
                        eval_curve = snap.eval_curve;
                        step = snap.next_step;
                        sentinel.rung = 1;
                        sentinel.consecutive_bad = 0;
                        continue;
                    }
                    None => match next_recipe(active_recipe) {
                        Some(next) => {
                            sentinel.escalations += 1;
                            report.escalations += 1;
                            crate::telemetry::incr(
                                crate::telemetry::Counter::SentinelEscalations,
                                1,
                            );
                            let detail = format!("recipe {active_recipe} → {next}");
                            sentinel.interventions.push(Intervention {
                                step,
                                kind: InterventionKind::Escalate,
                                detail: detail.clone(),
                            });
                            report.interventions.push(Intervention {
                                step,
                                kind: InterventionKind::Escalate,
                                detail,
                            });
                            active_recipe = next;
                            model.gemm.set_recipe(next);
                            sentinel.rung = 0;
                            sentinel.consecutive_bad = 0;
                        }
                        None => {
                            sentinel.ladder_dead = true;
                            report.ladder_dead = true;
                            sentinel.consecutive_bad = 0;
                            let detail = "ladder exhausted; skipping remaining bad steps";
                            sentinel.interventions.push(Intervention {
                                step,
                                kind: InterventionKind::Escalate,
                                detail: detail.to_string(),
                            });
                            report.interventions.push(Intervention {
                                step,
                                kind: InterventionKind::Escalate,
                                detail: detail.to_string(),
                            });
                        }
                    },
                }
            }
            step += 1;
            continue;
        }
        sentinel.consecutive_bad = 0;
        clip_global_norm(&mut grads, cfg.grad_clip);
        opt.update(&mut params, &mut grads, sched.lr_at(step));
        drop(step_span);
        ema = Some(match ema {
            None => loss,
            Some(e) => 0.95 * e + 0.05 * loss,
        });
        loss_curve.push((step, loss));
        if cfg.eval_every > 0 && (step + 1) % cfg.eval_every == 0 {
            let ev = evaluate(&mut model, &params, &eval_set, cfg.batch, cfg.seq);
            eval_curve.push((step, ev));
            // periodic JSONL snapshot at the eval cadence; telemetry must
            // never fail a training run, so I/O errors only warn
            if let Err(e) = crate::telemetry::write_snapshot("train", step + 1) {
                eprintln!("warning: telemetry snapshot failed: {e}");
            }
        }
        // checkpoint cadence sits *after* the eval block: held-out eval
        // consumes auxiliary stream draws under some recipes, and the
        // record must capture the cursors a resumed run will start from
        if ckpt_every > 0 && (step + 1) % ckpt_every == 0 {
            if let Some(dir) = ckpt_dir.as_ref() {
                let (sr_cursor, aux_rng) = model.gemm.stream_cursors();
                let (m, v) = opt.moments();
                let snap = TrainSnapshot {
                    next_step: step + 1,
                    seed: cfg.seed,
                    steps: cfg.steps,
                    batch: cfg.batch,
                    seq: cfg.seq,
                    peak_lr: cfg.peak_lr,
                    grad_clip: cfg.grad_clip,
                    eval_every: cfg.eval_every,
                    eval_batches: cfg.eval_batches,
                    model_cfg,
                    base_recipe: recipe,
                    active_recipe,
                    params: params.clone(),
                    opt_m: m.clone(),
                    opt_v: v.clone(),
                    opt_step: opt.step,
                    batcher_rng: batcher.rng_state(),
                    sr_cursor,
                    aux_rng,
                    ema,
                    loss_curve: loss_curve.clone(),
                    eval_curve: eval_curve.clone(),
                    wall_seconds: wall_accum + t0.elapsed().as_secs_f64(),
                    sentinel: sentinel.clone(),
                };
                checkpoint::write_record(dir, &snap, opts.checkpoint.keep, &opts.faults)?;
                report.checkpoints_written += 1;
            }
        }
        step += 1;
    }
    let wall = wall_accum + t0.elapsed().as_secs_f64();
    let final_eval = evaluate(&mut model, &params, &eval_set, cfg.batch, cfg.seq);
    eval_curve.push((cfg.steps, final_eval));
    if let Err(e) = crate::telemetry::write_snapshot("train", cfg.steps) {
        eprintln!("warning: telemetry snapshot failed: {e}");
    }
    Ok(TrainResult {
        recipe,
        final_train_loss: ema.unwrap_or(f32::NAN),
        final_eval_loss: final_eval,
        loss_curve,
        eval_curve,
        params,
        taps: captured,
        wall_seconds: wall,
        sec_per_step: wall / cfg.steps.max(1) as f64,
        final_recipe: active_recipe,
        report,
    })
}

/// Mean held-out loss over a fixed eval set.
pub fn evaluate(
    model: &mut Transformer,
    params: &Params,
    eval_set: &[(Vec<u32>, Vec<u32>)],
    batch: usize,
    seq: usize,
) -> f32 {
    // an empty eval set used to yield a silent NaN that poisoned summary
    // tables downstream; it is always a configuration bug, so fail loudly
    assert!(!eval_set.is_empty(), "evaluate called with an empty eval set (eval_batches = 0?)");
    let mut acc = 0.0f64;
    for (x, y) in eval_set {
        acc += model.eval_loss(params, x, y, batch, seq) as f64;
    }
    (acc / eval_set.len() as f64) as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Corpus, CorpusConfig};

    fn mini_corpus() -> Corpus {
        Corpus::generate(CorpusConfig { tokens: 1 << 14, vocab: 64, ..Default::default() }, 5)
    }

    #[test]
    fn short_bf16_run_reduces_loss() {
        let c = mini_corpus();
        let cfg = TrainConfig { steps: 30, batch: 4, seq: 16, eval_every: 0, ..Default::default() };
        let r = train(
            ModelConfig::test_tiny(64),
            QuantRecipe::Bf16,
            cfg,
            c.train.clone(),
            c.heldout.clone(),
        );
        let first = r.loss_curve.first().unwrap().1;
        let last = r.final_train_loss;
        assert!(last < first, "loss should drop: {first} → {last}");
        assert!(r.final_eval_loss.is_finite());
        // healthy run: the sentinel observed but never intervened
        assert_eq!(r.report.skipped_steps, 0);
        assert!(r.report.interventions.is_empty());
        assert_eq!(r.final_recipe, QuantRecipe::Bf16);
    }

    #[test]
    fn taps_captured_at_requested_checkpoints() {
        let c = mini_corpus();
        let cfg = TrainConfig {
            steps: 24,
            batch: 2,
            seq: 16,
            eval_every: 0,
            tap_steps: [true, true],
            ..Default::default()
        };
        let r = train(
            ModelConfig::test_tiny(64),
            QuantRecipe::Bf16,
            cfg,
            c.train.clone(),
            c.heldout.clone(),
        );
        assert_eq!(r.taps.len(), 2);
        assert_eq!(r.taps[0].0, "early");
        assert_eq!(r.taps[1].0, "late");
        assert!(!r.taps[0].1.is_empty());
    }

    #[test]
    fn same_seed_same_curve() {
        let c = mini_corpus();
        let cfg = TrainConfig { steps: 10, batch: 2, seq: 16, eval_every: 0, ..Default::default() };
        let r1 = train(
            ModelConfig::test_tiny(64),
            QuantRecipe::Nvfp4,
            cfg,
            c.train.clone(),
            c.heldout.clone(),
        );
        let r2 = train(
            ModelConfig::test_tiny(64),
            QuantRecipe::Nvfp4,
            cfg,
            c.train.clone(),
            c.heldout.clone(),
        );
        assert_eq!(r1.loss_curve, r2.loss_curve);
    }

    #[test]
    fn same_seed_same_curve_at_any_thread_count() {
        // the deterministic-parallelism contract: SR streams are
        // counter-seeded per row block and GeMM row sharding never changes
        // accumulation order, so 1, 2, and 4 workers give identical curves
        let c = mini_corpus();
        let run = |threads: usize| {
            let cfg = TrainConfig {
                steps: 8,
                batch: 2,
                seq: 16,
                eval_every: 0,
                threads,
                ..Default::default()
            };
            train(
                ModelConfig::test_tiny(64),
                QuantRecipe::Averis,
                cfg,
                c.train.clone(),
                c.heldout.clone(),
            )
        };
        let r1 = run(1);
        let r2 = run(2);
        let r4 = run(4);
        assert_eq!(r1.loss_curve, r2.loss_curve, "1 vs 2 threads");
        assert_eq!(r1.loss_curve, r4.loss_curve, "1 vs 4 threads");
    }

    #[test]
    #[should_panic(expected = "empty eval set")]
    fn evaluate_rejects_empty_eval_set() {
        let cfg = ModelConfig::test_tiny(64);
        let params = Params::init(&cfg, &mut Rng::new(1));
        let mut model = Transformer::new(cfg, QuantRecipe::Bf16, 0);
        let _ = evaluate(&mut model, &params, &[], 2, 16);
    }
}
