//! Training substrate: AdamW optimizer, LR schedule, gradient clipping,
//! the single-process training loop over the pure-Rust simulator, and the
//! crash-safe train-state checkpointing + numerics sentinel that ride on
//! it (DESIGN.md §13).
//! (The PJRT-artifact training loop lives in `coordinator`.)

pub mod checkpoint;
pub mod loop_;
pub mod optimizer;
pub mod schedule;

pub use checkpoint::{
    find_latest_valid, list_records, loss_curve_checksum, record_path, Intervention,
    InterventionKind, SentinelState, TrainSnapshot,
};
pub use loop_::{
    train, train_with, CheckpointConfig, SentinelConfig, TrainConfig, TrainOptions, TrainReport,
    TrainResult,
};
pub use optimizer::{AdamW, AdamWConfig};
pub use schedule::LrSchedule;
