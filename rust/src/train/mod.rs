//! Training substrate: AdamW optimizer, LR schedule, gradient clipping, and
//! the single-process training loop over the pure-Rust simulator.
//! (The PJRT-artifact training loop lives in `coordinator`.)

pub mod loop_;
pub mod optimizer;
pub mod schedule;

pub use loop_::{train, TrainConfig, TrainResult};
pub use optimizer::{AdamW, AdamWConfig};
pub use schedule::LrSchedule;
