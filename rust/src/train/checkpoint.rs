//! Crash-safe train-state records (DESIGN.md §13): a versioned,
//! CRC32-checksummed snapshot of *everything* the step loop consumes —
//! parameters, AdamW moments, LR-schedule position, counter-seeded SR and
//! sampling stream cursors, loss EMA, corpus cursor, and the numerics
//! sentinel's ladder position — written atomically (tmp + fsync + rename)
//! at a fixed cadence with keep-last-K retention.
//!
//! The resume contract: restoring the newest valid record continues the
//! loss curve **bit for bit** against an uninterrupted run, at any thread
//! count and any forced SIMD level. The argument has two halves. Every
//! stochastic stream in the loop is counter-seeded (`quant::sr::SrStream`,
//! `tensor::Rng`), so its entire future is determined by a small cursor
//! this record captures; and every sentinel decision is a pure function of
//! per-step data (loss, pre-clip grad norm, step index), so a resumed run
//! replays the same interventions it would have taken uninterrupted.
//!
//! Activation taps are deliberately *not* serialized: a resumed run only
//! re-captures taps whose steps lie after the resume point.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::model::{ModelConfig, Params};
use crate::quant::QuantRecipe;
use crate::runtime::wire::{
    append_crc_trailer, check_crc_trailer, crc32, put_bytes, put_f32, put_f32s, put_u32, put_u64,
    put_u8, read_ckpt_file, write_ckpt_file, Reader,
};
use crate::serve::checkpoint::{put_config, read_config};
use crate::serve::FaultPlan;
use crate::tensor::{Rng, RngState};

use super::loop_::TrainConfig;

/// Magic prefix of a train-state record ("AVTS").
pub const TRAIN_STATE_MAGIC: u32 = 0x4156_5453;
/// Train-state records have carried a CRC trailer from their first version.
const TRAIN_STATE_VERSION: u32 = 1;

/// What the sentinel did at one step.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum InterventionKind {
    /// Discard the step's gradients; optimizer and params untouched.
    SkipStep,
    /// Restore all numeric state from the newest valid on-disk record.
    Rollback,
    /// Switch the quantization recipe one rung down the ladder.
    Escalate,
}

impl InterventionKind {
    pub fn name(self) -> &'static str {
        match self {
            InterventionKind::SkipStep => "skip_step",
            InterventionKind::Rollback => "rollback",
            InterventionKind::Escalate => "escalate",
        }
    }

    fn code(self) -> u8 {
        match self {
            InterventionKind::SkipStep => 0,
            InterventionKind::Rollback => 1,
            InterventionKind::Escalate => 2,
        }
    }

    fn from_code(c: u8) -> Result<InterventionKind> {
        Ok(match c {
            0 => InterventionKind::SkipStep,
            1 => InterventionKind::Rollback,
            2 => InterventionKind::Escalate,
            other => bail!("unknown intervention code {other}"),
        })
    }
}

/// One recorded sentinel decision.
#[derive(Clone, Debug, PartialEq)]
pub struct Intervention {
    pub step: u64,
    pub kind: InterventionKind,
    pub detail: String,
}

/// The sentinel ladder's position, serialized so a resumed run continues
/// the intervention sequence instead of restarting it.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SentinelState {
    /// Bad steps seen since the last good step or intervention.
    pub consecutive_bad: u32,
    /// 0 = the next escalation is a rollback, 1 = a recipe escalation.
    pub rung: u8,
    pub rollbacks: u32,
    pub escalations: u32,
    pub skipped: u32,
    /// The recipe ladder is exhausted; only skip-step remains.
    pub ladder_dead: bool,
    pub interventions: Vec<Intervention>,
}

/// Everything the step loop consumes, captured at a step boundary.
///
/// (Named `TrainSnapshot` — `runtime::executor` already owns the name
/// `TrainState` for the PJRT device-buffer set.)
pub struct TrainSnapshot {
    /// The step the resumed loop executes first.
    pub next_step: u64,
    // -- guard fields: a resume refuses to continue under a different run --
    pub seed: u64,
    pub steps: u64,
    pub batch: usize,
    pub seq: usize,
    pub peak_lr: f32,
    pub grad_clip: f32,
    pub eval_every: u64,
    pub eval_batches: usize,
    pub model_cfg: ModelConfig,
    /// The recipe the run was launched with (the guard), as opposed to the
    /// recipe the sentinel may have escalated to.
    pub base_recipe: QuantRecipe,
    // -------------------------------------------------- numeric state --
    pub active_recipe: QuantRecipe,
    pub params: Params,
    pub opt_m: Params,
    pub opt_v: Params,
    pub opt_step: u64,
    /// Corpus cursor: the batcher's shuffle-RNG position.
    pub batcher_rng: RngState,
    /// Counter-seeded stochastic-rounding stream position.
    pub sr_cursor: u64,
    /// Auxiliary (Hadamard-sign / SVD power-iteration) stream position.
    pub aux_rng: RngState,
    pub ema: Option<f32>,
    pub loss_curve: Vec<(u64, f32)>,
    pub eval_curve: Vec<(u64, f32)>,
    /// Wall-clock seconds accumulated before this record was written.
    pub wall_seconds: f64,
    pub sentinel: SentinelState,
}

fn put_params(out: &mut Vec<u8>, p: &Params) {
    let mut n = 0u32;
    p.for_each(|_| n += 1);
    put_u32(out, n);
    p.for_each(|s| put_f32s(out, s));
}

fn read_params(r: &mut Reader<'_>, cfg: &ModelConfig) -> Result<Params> {
    let n_tensors = r.u32()? as usize;
    // shape-correct constructor; every tensor is overwritten below
    let mut params = Params::init(cfg, &mut Rng::new(0));
    let mut expect = 0usize;
    params.for_each(|_| expect += 1);
    if n_tensors != expect {
        bail!("record has {n_tensors} tensors, config implies {expect}");
    }
    let mut err: Option<anyhow::Error> = None;
    params.for_each_mut(|s| {
        if err.is_some() {
            return;
        }
        match r.f32s() {
            Ok(v) if v.len() == s.len() => s.copy_from_slice(&v),
            Ok(v) => {
                err = Some(anyhow!("tensor length {} != expected {}", v.len(), s.len()));
            }
            Err(e) => err = Some(e.into()),
        }
    });
    match err {
        Some(e) => Err(e),
        None => Ok(params),
    }
}

fn put_rng_state(out: &mut Vec<u8>, st: &RngState) {
    for w in st.s {
        put_u64(out, w);
    }
    match st.spare_normal {
        Some(x) => {
            put_u8(out, 1);
            put_f32(out, x);
        }
        None => {
            put_u8(out, 0);
            put_f32(out, 0.0);
        }
    }
}

fn read_rng_state(r: &mut Reader<'_>) -> Result<RngState> {
    let mut s = [0u64; 4];
    for w in &mut s {
        *w = r.u64()?;
    }
    let has_spare = r.u8()? != 0;
    let spare = r.f32()?;
    Ok(RngState { s, spare_normal: if has_spare { Some(spare) } else { None } })
}

fn put_curve(out: &mut Vec<u8>, curve: &[(u64, f32)]) {
    put_u32(out, curve.len() as u32);
    for &(step, v) in curve {
        put_u64(out, step);
        put_f32(out, v);
    }
}

fn read_curve(r: &mut Reader<'_>) -> Result<Vec<(u64, f32)>> {
    let n = r.u32()? as usize;
    (0..n).map(|_| Ok((r.u64()?, r.f32()?))).collect()
}

fn put_recipe(out: &mut Vec<u8>, recipe: QuantRecipe) {
    put_bytes(out, recipe.to_string().as_bytes());
}

fn read_recipe(r: &mut Reader<'_>) -> Result<QuantRecipe> {
    let raw = r.bytes()?;
    let s = std::str::from_utf8(&raw).context("recipe name is not utf-8")?;
    s.parse::<QuantRecipe>().map_err(|e| anyhow!(e))
}

impl TrainSnapshot {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        put_u32(&mut out, TRAIN_STATE_MAGIC);
        put_u32(&mut out, TRAIN_STATE_VERSION);
        put_u64(&mut out, self.next_step);
        put_u64(&mut out, self.seed);
        put_u64(&mut out, self.steps);
        put_u64(&mut out, self.batch as u64);
        put_u64(&mut out, self.seq as u64);
        put_f32(&mut out, self.peak_lr);
        put_f32(&mut out, self.grad_clip);
        put_u64(&mut out, self.eval_every);
        put_u64(&mut out, self.eval_batches as u64);
        put_config(&mut out, &self.model_cfg);
        put_recipe(&mut out, self.base_recipe);
        put_recipe(&mut out, self.active_recipe);
        put_params(&mut out, &self.params);
        put_params(&mut out, &self.opt_m);
        put_params(&mut out, &self.opt_v);
        put_u64(&mut out, self.opt_step);
        put_rng_state(&mut out, &self.batcher_rng);
        put_u64(&mut out, self.sr_cursor);
        put_rng_state(&mut out, &self.aux_rng);
        match self.ema {
            Some(e) => {
                put_u8(&mut out, 1);
                put_f32(&mut out, e);
            }
            None => {
                put_u8(&mut out, 0);
                put_f32(&mut out, 0.0);
            }
        }
        put_curve(&mut out, &self.loss_curve);
        put_curve(&mut out, &self.eval_curve);
        put_u64(&mut out, self.wall_seconds.to_bits());
        put_u32(&mut out, self.sentinel.consecutive_bad);
        put_u8(&mut out, self.sentinel.rung);
        put_u32(&mut out, self.sentinel.rollbacks);
        put_u32(&mut out, self.sentinel.escalations);
        put_u32(&mut out, self.sentinel.skipped);
        put_u8(&mut out, self.sentinel.ladder_dead as u8);
        put_u32(&mut out, self.sentinel.interventions.len() as u32);
        for iv in &self.sentinel.interventions {
            put_u64(&mut out, iv.step);
            put_u8(&mut out, iv.kind.code());
            put_bytes(&mut out, iv.detail.as_bytes());
        }
        append_crc_trailer(&mut out);
        out
    }

    pub fn decode(bytes: &[u8]) -> Result<TrainSnapshot> {
        let mut head = Reader::new(bytes);
        let magic = head.u32()?;
        if magic != TRAIN_STATE_MAGIC {
            bail!("not a train-state record (magic {magic:#x})");
        }
        let version = head.u32()?;
        if version != TRAIN_STATE_VERSION {
            bail!("unsupported train-state version {version}");
        }
        let body = check_crc_trailer(bytes)?;
        let mut r = Reader::new(body);
        let _ = r.u32()?; // magic, validated above
        let _ = r.u32()?; // version
        let next_step = r.u64()?;
        let seed = r.u64()?;
        let steps = r.u64()?;
        let batch = r.u64()? as usize;
        let seq = r.u64()? as usize;
        let peak_lr = r.f32()?;
        let grad_clip = r.f32()?;
        let eval_every = r.u64()?;
        let eval_batches = r.u64()? as usize;
        let model_cfg = read_config(&mut r)?;
        let base_recipe = read_recipe(&mut r)?;
        let active_recipe = read_recipe(&mut r)?;
        let params = read_params(&mut r, &model_cfg)?;
        let opt_m = read_params(&mut r, &model_cfg)?;
        let opt_v = read_params(&mut r, &model_cfg)?;
        let opt_step = r.u64()?;
        let batcher_rng = read_rng_state(&mut r)?;
        let sr_cursor = r.u64()?;
        let aux_rng = read_rng_state(&mut r)?;
        let has_ema = r.u8()? != 0;
        let ema_val = r.f32()?;
        let loss_curve = read_curve(&mut r)?;
        let eval_curve = read_curve(&mut r)?;
        let wall_seconds = f64::from_bits(r.u64()?);
        let consecutive_bad = r.u32()?;
        let rung = r.u8()?;
        let rollbacks = r.u32()?;
        let escalations = r.u32()?;
        let skipped = r.u32()?;
        let ladder_dead = r.u8()? != 0;
        let n_iv = r.u32()? as usize;
        let interventions = (0..n_iv)
            .map(|_| {
                let step = r.u64()?;
                let kind = InterventionKind::from_code(r.u8()?)?;
                let raw = r.bytes()?;
                let detail =
                    String::from_utf8(raw).context("intervention detail is not utf-8")?;
                Ok(Intervention { step, kind, detail })
            })
            .collect::<Result<Vec<_>>>()?;
        r.done()?;
        Ok(TrainSnapshot {
            next_step,
            seed,
            steps,
            batch,
            seq,
            peak_lr,
            grad_clip,
            eval_every,
            eval_batches,
            model_cfg,
            base_recipe,
            active_recipe,
            params,
            opt_m,
            opt_v,
            opt_step,
            batcher_rng,
            sr_cursor,
            aux_rng,
            ema: if has_ema { Some(ema_val) } else { None },
            loss_curve,
            eval_curve,
            wall_seconds,
            sentinel: SentinelState {
                consecutive_bad,
                rung,
                rollbacks,
                escalations,
                skipped,
                ladder_dead,
                interventions,
            },
        })
    }

    /// Refuse to resume under different hyperparameters, model geometry, or
    /// launch recipe. Thread count and SIMD level are deliberately absent:
    /// the bitwise-resume invariant holds across both.
    pub fn check_guard(
        &self,
        model_cfg: &ModelConfig,
        base_recipe: QuantRecipe,
        cfg: &TrainConfig,
    ) -> Result<()> {
        let mut a = Vec::new();
        put_config(&mut a, model_cfg);
        let mut b = Vec::new();
        put_config(&mut b, &self.model_cfg);
        if a != b {
            bail!("resume: model config differs from the checkpointed run");
        }
        if base_recipe != self.base_recipe {
            bail!("resume: recipe {base_recipe} differs from checkpointed {}", self.base_recipe);
        }
        let same = self.seed == cfg.seed
            && self.steps == cfg.steps
            && self.batch == cfg.batch
            && self.seq == cfg.seq
            && self.peak_lr.to_bits() == cfg.peak_lr.to_bits()
            && self.grad_clip.to_bits() == cfg.grad_clip.to_bits()
            && self.eval_every == cfg.eval_every
            && self.eval_batches == cfg.eval_batches;
        if !same {
            bail!("resume: training hyperparameters differ from the checkpointed run");
        }
        Ok(())
    }
}

/// `trainstate-<step>.avts` path for a record whose resumed loop starts at
/// `next_step`.
pub fn record_path(dir: &Path, next_step: u64) -> PathBuf {
    dir.join(format!("trainstate-{next_step:08}.avts"))
}

fn record_step(path: &Path) -> Option<u64> {
    let name = path.file_name()?.to_str()?;
    name.strip_prefix("trainstate-")?.strip_suffix(".avts")?.parse().ok()
}

/// All train-state records in `dir`, ascending by step.
pub fn list_records(dir: &Path) -> Vec<(u64, PathBuf)> {
    let Ok(rd) = std::fs::read_dir(dir) else { return Vec::new() };
    let mut out: Vec<(u64, PathBuf)> = rd
        .flatten()
        .filter_map(|e| {
            let p = e.path();
            record_step(&p).map(|step| (step, p))
        })
        .collect();
    out.sort();
    out
}

/// Write `snap` durably (tmp + fsync + rename, fault-injectable) and prune
/// to the newest `keep` records. Returns the record's path.
pub fn write_record(
    dir: &Path,
    snap: &TrainSnapshot,
    keep: usize,
    faults: &FaultPlan,
) -> Result<PathBuf> {
    std::fs::create_dir_all(dir)
        .with_context(|| format!("creating checkpoint dir {}", dir.display()))?;
    let path = record_path(dir, snap.next_step);
    write_ckpt_file(&path, &snap.encode(), faults)
        .with_context(|| format!("writing {}", path.display()))?;
    crate::telemetry::incr(crate::telemetry::Counter::CkptWrites, 1);
    let records = list_records(dir);
    if keep > 0 && records.len() > keep {
        for (_, old) in &records[..records.len() - keep] {
            let _ = std::fs::remove_file(old);
        }
    }
    Ok(path)
}

/// Newest record in `dir` that reads back and passes its checksum. Torn or
/// corrupt records are *skipped with a warning*, not errors — surviving a
/// crash mid-write by falling back to the previous record is the normal
/// recovery path. `None` if no valid record remains.
pub fn find_latest_valid(dir: &Path, faults: &FaultPlan) -> Option<(PathBuf, TrainSnapshot)> {
    let mut records = list_records(dir);
    records.reverse();
    for (_, path) in records {
        let parsed = read_ckpt_file(&path, faults)
            .map_err(anyhow::Error::from)
            .and_then(|bytes| TrainSnapshot::decode(&bytes));
        match parsed {
            Ok(snap) => return Some((path, snap)),
            Err(e) => {
                eprintln!("warning: skipping unreadable train-state {}: {e}", path.display());
            }
        }
    }
    None
}

/// CRC32 over the loss curve's (step, loss-bits) pairs — the one-line
/// invariant the kill-and-resume CI leg greps for and compares.
pub fn loss_curve_checksum(curve: &[(u64, f32)]) -> u32 {
    let mut buf = Vec::with_capacity(curve.len() * 12);
    for &(step, loss) in curve {
        put_u64(&mut buf, step);
        put_u32(&mut buf, loss.to_bits());
    }
    crc32(&buf)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params_bits(p: &Params) -> Vec<u32> {
        let mut out = Vec::new();
        p.for_each(|s| out.extend(s.iter().map(|x| x.to_bits())));
        out
    }

    fn sample_snapshot() -> TrainSnapshot {
        let cfg = ModelConfig::test_tiny(32);
        let mut rng = Rng::new(9);
        let params = Params::init(&cfg, &mut rng);
        let opt_m = params.zeros_like();
        let opt_v = params.zeros_like();
        TrainSnapshot {
            next_step: 7,
            seed: 1234,
            steps: 20,
            batch: 2,
            seq: 16,
            peak_lr: 3e-3,
            grad_clip: 1.0,
            eval_every: 5,
            eval_batches: 2,
            model_cfg: cfg,
            base_recipe: QuantRecipe::Nvfp4,
            active_recipe: QuantRecipe::Averis,
            params,
            opt_m,
            opt_v,
            opt_step: 7,
            batcher_rng: RngState { s: [1, 2, 3, 4], spare_normal: Some(0.25) },
            sr_cursor: 99,
            aux_rng: RngState { s: [5, 6, 7, 8], spare_normal: None },
            ema: Some(3.5),
            loss_curve: vec![(0, 4.0), (1, 3.9)],
            eval_curve: vec![(1, 4.1)],
            wall_seconds: 1.5,
            sentinel: SentinelState {
                consecutive_bad: 1,
                rung: 1,
                rollbacks: 1,
                escalations: 1,
                skipped: 3,
                ladder_dead: false,
                interventions: vec![Intervention {
                    step: 3,
                    kind: InterventionKind::SkipStep,
                    detail: "loss=NaN".into(),
                }],
            },
        }
    }

    #[test]
    fn roundtrip_is_bitwise() {
        let snap = sample_snapshot();
        let back = TrainSnapshot::decode(&snap.encode()).unwrap();
        assert_eq!(back.next_step, snap.next_step);
        assert_eq!(back.seed, snap.seed);
        assert_eq!(back.base_recipe, QuantRecipe::Nvfp4);
        assert_eq!(back.active_recipe, QuantRecipe::Averis);
        assert_eq!(params_bits(&back.params), params_bits(&snap.params));
        assert_eq!(params_bits(&back.opt_m), params_bits(&snap.opt_m));
        assert_eq!(params_bits(&back.opt_v), params_bits(&snap.opt_v));
        assert_eq!(back.opt_step, 7);
        assert_eq!(back.batcher_rng, snap.batcher_rng);
        assert_eq!(back.sr_cursor, 99);
        assert_eq!(back.aux_rng, snap.aux_rng);
        assert_eq!(back.ema.map(f32::to_bits), snap.ema.map(f32::to_bits));
        assert_eq!(back.loss_curve, snap.loss_curve);
        assert_eq!(back.eval_curve, snap.eval_curve);
        assert_eq!(back.wall_seconds.to_bits(), snap.wall_seconds.to_bits());
        assert_eq!(back.sentinel, snap.sentinel);
        // and the guard accepts its own run parameters
        let cfg = TrainConfig {
            steps: 20,
            batch: 2,
            seq: 16,
            peak_lr: 3e-3,
            grad_clip: 1.0,
            eval_every: 5,
            eval_batches: 2,
            seed: 1234,
            ..Default::default()
        };
        back.check_guard(&snap.model_cfg, QuantRecipe::Nvfp4, &cfg).unwrap();
        assert!(back.check_guard(&snap.model_cfg, QuantRecipe::Mxfp4, &cfg).is_err());
        let other = TrainConfig { seed: 99, ..cfg };
        assert!(back.check_guard(&snap.model_cfg, QuantRecipe::Nvfp4, &other).is_err());
    }

    #[test]
    fn corruption_is_rejected() {
        let bytes = sample_snapshot().encode();
        assert!(TrainSnapshot::decode(&bytes[..bytes.len() - 9]).is_err());
        let mut flipped = bytes.clone();
        let mid = flipped.len() / 2;
        flipped[mid] ^= 0x02;
        assert!(TrainSnapshot::decode(&flipped).is_err());
        let mut wrong_magic = bytes.clone();
        wrong_magic[0] ^= 0xFF;
        assert!(TrainSnapshot::decode(&wrong_magic).is_err());
        TrainSnapshot::decode(&bytes).unwrap();
    }

    #[test]
    fn retention_keeps_last_k_and_resume_picks_newest_valid() {
        let dir = std::env::temp_dir().join(format!("averis-ts-retain-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let clean = FaultPlan::none();
        for step in 1..=4u64 {
            let mut snap = sample_snapshot();
            snap.next_step = step;
            write_record(&dir, &snap, 3, &clean).unwrap();
        }
        let records = list_records(&dir);
        assert_eq!(records.iter().map(|(s, _)| *s).collect::<Vec<_>>(), vec![2, 3, 4]);
        // truncate the newest on disk: resume must fall back to step 3
        let newest = record_path(&dir, 4);
        let bytes = std::fs::read(&newest).unwrap();
        std::fs::write(&newest, &bytes[..bytes.len() / 2]).unwrap();
        let (path, snap) = find_latest_valid(&dir, &clean).unwrap();
        assert_eq!(path, record_path(&dir, 3));
        assert_eq!(snap.next_step, 3);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_write_fault_falls_back_to_previous_record() {
        let dir = std::env::temp_dir().join(format!("averis-ts-torn-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let clean = FaultPlan::none();
        let torn = FaultPlan::parse("ckpt_torn_write:1", 0).unwrap();
        let mut snap = sample_snapshot();
        snap.next_step = 1;
        write_record(&dir, &snap, 3, &clean).unwrap();
        snap.next_step = 2;
        write_record(&dir, &snap, 3, &torn).unwrap();
        let (path, back) = find_latest_valid(&dir, &clean).unwrap();
        assert_eq!(path, record_path(&dir, 1));
        assert_eq!(back.next_step, 1);
        // with nothing valid at all, resume reports None (fresh start)
        let bytes = std::fs::read(record_path(&dir, 1)).unwrap();
        std::fs::write(record_path(&dir, 1), &bytes[..10]).unwrap();
        assert!(find_latest_valid(&dir, &clean).is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn loss_curve_checksum_is_order_and_bit_sensitive() {
        let a = vec![(0u64, 4.0f32), (1, 3.5)];
        let b = vec![(1u64, 3.5f32), (0, 4.0)];
        assert_ne!(loss_curve_checksum(&a), loss_curve_checksum(&b));
        let mut c = a.clone();
        c[1].1 = f32::from_bits(c[1].1.to_bits() ^ 1);
        assert_ne!(loss_curve_checksum(&a), loss_curve_checksum(&c));
        let again = vec![(0u64, 4.0f32), (1, 3.5)];
        assert_eq!(loss_curve_checksum(&a), loss_curve_checksum(&again));
    }
}
