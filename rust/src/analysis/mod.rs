//! Mean-bias analysis pipeline — reproduces the measurements of paper §2 and
//! the appendices on activations captured from the simulator's taps:
//!
//!  * `meanbias`   — ratio R, μ–v_k alignment, token-cos diagnostics (Fig. 1/2)
//!  * `operator_trace` — per-operator R and adjacent-stage mean-cos (Fig. 3)
//!  * `attribution` — top-0.1% outlier mean/residual shares (Fig. 4)
//!  * `gaussian_fit` — raw-vs-residual Gaussianity, QQ data (Fig. 5)
//!  * `variance`   — diagonal variance approximation check (App. B)
//!  * `tails`      — raw-vs-residual tail contraction (App. C)
//!  * `theorem1`   — Monte-Carlo + closed-form validation of Theorem 1

pub mod attribution;
pub mod gaussian_fit;
pub mod meanbias;
pub mod operator_trace;
pub mod tails;
pub mod theorem1;
pub mod variance;

pub use attribution::{outlier_attribution, AttributionStats};
pub use meanbias::{mean_bias_ratio, MeanBiasReport};
