//! Diagonal variance approximation check (paper Assumption 2 / App. B):
//! compare the empirical per-column residual variance τ²_j against the
//! diagonal spectral estimate  τ²_{j,diag} = (1/l) Σ_k σ_k²(1−β_k²) v_kj²
//! and report the relative cross-term contribution.

use crate::linalg::svd;
use crate::tensor::ops::{median, percentile};
use crate::tensor::Mat;

/// Per-column comparison result.
#[derive(Clone, Debug)]
pub struct VarianceCheck {
    pub empirical: Vec<f32>,
    pub diagonal: Vec<f32>,
    /// |empirical − diagonal| / empirical per column
    pub rel_cross_term: Vec<f32>,
    pub median_cross: f32,
    pub p95_cross: f32,
}

/// Run the App.-B validation on one activation matrix. Uses a full Jacobi
/// SVD, so sub-sample large matrices first (the analysis pipeline passes
/// ≤512×512 slices).
pub fn diagonal_variance_check(x: &Mat) -> VarianceCheck {
    let l = x.rows;
    let m = x.cols;
    let mu = x.col_mean();
    // empirical residual variance per column (biased, 1/l — matches the
    // row-sampling definition in the paper)
    let mut emp = vec![0.0f32; m];
    for i in 0..l {
        let row = x.row(i);
        for j in 0..m {
            let d = row[j] - mu[j];
            emp[j] += d * d;
        }
    }
    for e in emp.iter_mut() {
        *e /= l as f32;
    }
    // spectral quantities
    let d = svd(x);
    let r = d.s.len();
    // β_k = <u_k, 1/√l>
    let betas: Vec<f32> = (0..r)
        .map(|k| (0..l).map(|i| d.u.at(i, k)).sum::<f32>() / (l as f32).sqrt())
        .collect();
    let mut diag = vec![0.0f32; m];
    for k in 0..r {
        let c = d.s[k] * d.s[k] * (1.0 - betas[k] * betas[k]) / l as f32;
        for j in 0..m {
            let v = d.v.at(j, k);
            diag[j] += c * v * v;
        }
    }
    let rel: Vec<f32> = emp
        .iter()
        .zip(diag.iter())
        .map(|(&e, &dg)| if e > 1e-12 { (e - dg).abs() / e } else { 0.0 })
        .collect();
    VarianceCheck {
        median_cross: median(&rel),
        p95_cross: percentile(&rel, 95.0),
        empirical: emp,
        diagonal: diag,
        rel_cross_term: rel,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    #[test]
    fn diagonal_estimate_tracks_empirical_on_gaussian_plus_spike() {
        // the paper's validated regime: anisotropic activation-like matrix
        let mut rng = Rng::new(190);
        let mut x = Mat::randn(96, 48, 0.5, &mut rng);
        let mu = Mat::randn(1, 48, 2.0, &mut rng);
        x.add_row_vec(&mu.data);
        let c = diagonal_variance_check(&x);
        // paper App. B reports median 0.006, p95 0.036; we accept the same
        // order of magnitude
        assert!(c.median_cross < 0.15, "median cross {}", c.median_cross);
        assert!(c.p95_cross < 0.5, "p95 cross {}", c.p95_cross);
    }

    #[test]
    fn exact_identity_when_svd_exact() {
        // The identity Var_j = Σ_k,k' cross-terms holds exactly; diagonal
        // approx == empirical when cross-terms vanish, e.g. rank-1 matrices.
        let mut rng = Rng::new(191);
        let u = Mat::randn(32, 1, 1.0, &mut rng);
        let v = Mat::randn(1, 16, 1.0, &mut rng);
        let x = u.matmul(&v);
        let c = diagonal_variance_check(&x);
        assert!(c.median_cross < 1e-3, "rank-1 median cross {}", c.median_cross);
    }
}
