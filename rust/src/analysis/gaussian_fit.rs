//! Gaussianity diagnostics (paper Fig. 5 / Assumption 1): compare raw
//! activations vs mean-removed residuals against a Gaussian fit, via
//! excess kurtosis, a Jarque–Bera-style statistic, and QQ-plot data.

use crate::linalg::norm_ppf;
use crate::tensor::Mat;

/// Moments + normality statistics of a sample.
#[derive(Clone, Copy, Debug)]
pub struct FitStats {
    pub mean: f64,
    pub std: f64,
    pub skewness: f64,
    /// excess kurtosis (0 for a Gaussian)
    pub excess_kurtosis: f64,
    /// Jarque–Bera statistic (≈0 for Gaussian samples; grows with n for
    /// heavy-tailed data)
    pub jarque_bera: f64,
}

/// Compute moment statistics of a sample.
pub fn fit_stats(xs: &[f32]) -> FitStats {
    let n = xs.len() as f64;
    assert!(n >= 4.0);
    let mean = xs.iter().map(|&x| x as f64).sum::<f64>() / n;
    let mut m2 = 0.0;
    let mut m3 = 0.0;
    let mut m4 = 0.0;
    for &x in xs {
        let d = x as f64 - mean;
        m2 += d * d;
        m3 += d * d * d;
        m4 += d * d * d * d;
    }
    m2 /= n;
    m3 /= n;
    m4 /= n;
    let std = m2.sqrt();
    let skewness = if m2 > 0.0 { m3 / m2.powf(1.5) } else { 0.0 };
    let excess_kurtosis = if m2 > 0.0 { m4 / (m2 * m2) - 3.0 } else { 0.0 };
    let jb = n / 6.0 * (skewness * skewness + excess_kurtosis * excess_kurtosis / 4.0);
    FitStats { mean, std, skewness, excess_kurtosis, jarque_bera: jb }
}

/// QQ-plot data: (theoretical Gaussian quantile, empirical quantile) pairs at
/// `points` evenly spaced probability levels. A Gaussian sample lies on y=x
/// after standardization.
pub fn qq_data(xs: &[f32], points: usize) -> Vec<(f64, f64)> {
    let stats = fit_stats(xs);
    let mut sorted: Vec<f32> = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = sorted.len();
    let mut out = Vec::with_capacity(points);
    for k in 1..=points {
        let p = k as f64 / (points as f64 + 1.0);
        let theo = norm_ppf(p);
        let idx = ((p * n as f64) as usize).min(n - 1);
        let emp = (sorted[idx] as f64 - stats.mean) / stats.std.max(1e-12);
        out.push((theo, emp));
    }
    out
}

/// Raw-vs-residual comparison for one activation matrix (Fig. 5): returns
/// (raw stats, residual stats). The paper's claim: the residual is much
/// closer to Gaussian (smaller |excess kurtosis| / JB).
pub fn raw_vs_residual(x: &Mat) -> (FitStats, FitStats) {
    let raw = fit_stats(&x.data);
    let mu = x.col_mean();
    let mut r = x.clone();
    r.sub_row_vec(&mu);
    let res = fit_stats(&r.data);
    (raw, res)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    #[test]
    fn gaussian_sample_has_small_jb() {
        let mut rng = Rng::new(180);
        let xs: Vec<f32> = (0..20_000).map(|_| rng.normal()).collect();
        let s = fit_stats(&xs);
        assert!(s.excess_kurtosis.abs() < 0.15, "kurt {}", s.excess_kurtosis);
        assert!(s.skewness.abs() < 0.1);
    }

    #[test]
    fn heavy_tailed_sample_flagged() {
        // mixture: mostly small + rare large → high kurtosis
        let mut rng = Rng::new(181);
        let xs: Vec<f32> = (0..20_000)
            .map(|_| if rng.uniform() < 0.01 { rng.normal() * 20.0 } else { rng.normal() })
            .collect();
        let s = fit_stats(&xs);
        assert!(s.excess_kurtosis > 5.0, "kurt {}", s.excess_kurtosis);
        assert!(s.jarque_bera > 1000.0);
    }

    #[test]
    fn qq_gaussian_on_diagonal() {
        let mut rng = Rng::new(182);
        let xs: Vec<f32> = (0..50_000).map(|_| rng.normal_ms(2.0, 3.0)).collect();
        for (theo, emp) in qq_data(&xs, 21) {
            assert!((theo - emp).abs() < 0.1, "qq ({theo},{emp})");
        }
    }

    #[test]
    fn mean_removal_restores_gaussianity() {
        // per-column means drawn from a wide distribution make the pooled raw
        // data strongly non-Gaussian; the residual is Gaussian by construction
        let mut rng = Rng::new(183);
        let mut x = Mat::randn(512, 64, 1.0, &mut rng);
        let mut mu = vec![0.0f32; 64];
        for (j, m) in mu.iter_mut().enumerate() {
            *m = if j % 8 == 0 { 12.0 } else { 0.0 };
        }
        x.add_row_vec(&mu);
        let (raw, res) = raw_vs_residual(&x);
        assert!(
            raw.excess_kurtosis.abs() > 3.0 * res.excess_kurtosis.abs().max(0.05),
            "raw kurt {} res kurt {}",
            raw.excess_kurtosis,
            res.excess_kurtosis
        );
    }
}
