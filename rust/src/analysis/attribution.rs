//! Outlier attribution (paper §2.3, Fig. 4): for the top-p% entries of X by
//! |magnitude|, measure the squared contribution shares of the rank-one mean
//! component vs the residual:
//!   ρ_mean = (M_X)²_ij / X²_ij,   ρ_res = X̃²_ij / X²_ij.

use crate::tensor::ops::{median, percentile};
use crate::tensor::Mat;

/// Attribution result over the top-quantile entry set.
#[derive(Clone, Debug)]
pub struct AttributionStats {
    /// per-entry mean shares ρ_mean for the top entries
    pub mean_shares: Vec<f32>,
    /// per-entry residual shares ρ_res
    pub res_shares: Vec<f32>,
    pub median_mean_share: f32,
    pub median_res_share: f32,
    /// fraction of top entries that are mean-dominated (ρ_mean > 0.5)
    pub frac_mean_dominated: f32,
}

/// Compute attribution over the top `top_frac` fraction of entries
/// (paper uses 0.001 = top-0.1%).
pub fn outlier_attribution(x: &Mat, top_frac: f64) -> AttributionStats {
    let n = x.numel();
    let k = ((n as f64 * top_frac).ceil() as usize).clamp(1, n);
    // threshold = (1-top_frac) quantile of |x|
    let abs: Vec<f32> = x.data.iter().map(|v| v.abs()).collect();
    let thresh = percentile(&abs, 100.0 * (1.0 - top_frac));
    let mu = x.col_mean();
    let mut mean_shares = Vec::with_capacity(k + 8);
    let mut res_shares = Vec::with_capacity(k + 8);
    for i in 0..x.rows {
        let row = x.row(i);
        for j in 0..x.cols {
            let v = row[j];
            if v.abs() < thresh || v == 0.0 {
                continue;
            }
            let m = mu[j];
            let r = v - m;
            let v2 = v * v;
            mean_shares.push((m * m / v2).min(4.0));
            res_shares.push((r * r / v2).min(4.0));
        }
    }
    if mean_shares.is_empty() {
        // degenerate (all-equal matrix): attribute everything to the mean
        mean_shares.push(1.0);
        res_shares.push(0.0);
    }
    let frac_dom =
        mean_shares.iter().filter(|&&s| s > 0.5).count() as f32 / mean_shares.len() as f32;
    AttributionStats {
        median_mean_share: median(&mean_shares),
        median_res_share: median(&res_shares),
        frac_mean_dominated: frac_dom,
        mean_shares,
        res_shares,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    #[test]
    fn pure_mean_matrix_fully_mean_attributed() {
        let mu = vec![5.0f32, -3.0, 2.0, 8.0];
        let mut x = Mat::zeros(64, 4);
        x.add_row_vec(&mu);
        let a = outlier_attribution(&x, 0.01);
        assert!(a.median_mean_share > 0.99);
        assert!(a.median_res_share < 0.01);
        assert!(a.frac_mean_dominated > 0.99);
    }

    #[test]
    fn zero_mean_noise_residual_attributed() {
        let mut rng = Rng::new(170);
        let mut x = Mat::randn(256, 64, 1.0, &mut rng);
        let mu = x.col_mean();
        x.sub_row_vec(&mu);
        let a = outlier_attribution(&x, 0.001);
        assert!(a.median_res_share > 0.95, "res share {}", a.median_res_share);
        assert!(a.frac_mean_dominated < 0.05);
    }

    #[test]
    fn strong_bias_shifts_attribution_to_mean() {
        // the paper's early→late transition: residual-dominated at low bias,
        // mean-dominated (~95% median share) at high bias
        let mut rng = Rng::new(171);
        let make = |bias: f32, noise: f32, rng: &mut Rng| {
            let mut x = Mat::randn(512, 128, noise, rng);
            let mut mu = vec![0.0f32; 128];
            // a few large-mean columns, like real outlier feature dims
            for j in (0..128).step_by(16) {
                mu[j] = bias;
            }
            x.add_row_vec(&mu);
            x
        };
        // early: weak mean, comparable noise → residual-dominated tops;
        // late: |m|/τ ≫ 1 → mean-dominated tops (paper: median share ≈ 95%)
        let early = outlier_attribution(&make(0.3, 0.5, &mut rng), 0.001);
        let late = outlier_attribution(&make(6.0, 0.1, &mut rng), 0.001);
        assert!(late.median_mean_share > 0.85, "late {}", late.median_mean_share);
        assert!(early.median_mean_share < 0.3, "early {}", early.median_mean_share);
        assert!(late.frac_mean_dominated > 0.9);
    }

    #[test]
    fn shares_roughly_complementary() {
        // ρ_mean + ρ_res + 2·cross = 1; for top entries the two shares should
        // bracket 1 from both sides on average
        let mut rng = Rng::new(172);
        let mut x = Mat::randn(128, 64, 1.0, &mut rng);
        let mu = Mat::randn(1, 64, 1.5, &mut rng);
        x.add_row_vec(&mu.data);
        let a = outlier_attribution(&x, 0.01);
        for (m, r) in a.mean_shares.iter().zip(a.res_shares.iter()) {
            let cross = 1.0 - m - r; // = 2·m̃·r̃/x²
            assert!(cross.abs() <= 2.0 + 1e-3, "m {m} r {r}");
        }
    }
}
