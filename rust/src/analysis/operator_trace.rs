//! Operator-level mean-bias trace (paper §2.2, Fig. 3): track the ratio R
//! and the adjacent-stage mean-direction cosine across the forward operator
//! chain of each block (block input → attn input → attn output → residual →
//! FFN input → FFN output → block output).

use super::meanbias::mean_bias_ratio;
use crate::model::{TapStage, Taps};
use crate::tensor::ops::cosine;

/// One stage's measurements.
#[derive(Clone, Debug)]
pub struct StagePoint {
    pub layer: usize,
    pub stage: TapStage,
    pub ratio: f32,
    /// cos(μ_this, μ_previous-stage); 1.0 for the first stage
    pub mean_cos_prev: f32,
}

/// Trace R and adjacent-stage mean cosines through every captured block.
pub fn operator_trace(taps: &Taps, n_layers: usize) -> Vec<StagePoint> {
    let mut out = Vec::new();
    for layer in 0..n_layers {
        let mut prev_mu: Option<Vec<f32>> = None;
        for stage in TapStage::FORWARD_CHAIN {
            let Some(x) = taps.get(layer, stage) else { continue };
            let mu = x.col_mean();
            let cos_prev = match &prev_mu {
                Some(p) if p.len() == mu.len() => cosine(p, &mu),
                _ => 1.0,
            };
            out.push(StagePoint { layer, stage, ratio: mean_bias_ratio(x), mean_cos_prev: cos_prev });
            prev_mu = Some(mu);
        }
    }
    out
}

/// Summary used by the Fig.-3 driver: does an operator amplify R, and how
/// much does it rotate the mean direction?
#[derive(Clone, Debug)]
pub struct OperatorEffect {
    pub layer: usize,
    pub operator: &'static str,
    pub r_in: f32,
    pub r_out: f32,
    pub mean_cos: f32,
}

/// Extract the attention and FFN operator effects per layer.
pub fn operator_effects(taps: &Taps, n_layers: usize) -> Vec<OperatorEffect> {
    let mut out = Vec::new();
    for layer in 0..n_layers {
        if let (Some(xin), Some(xout)) =
            (taps.get(layer, TapStage::AttnInput), taps.get(layer, TapStage::AttnOutput))
        {
            out.push(OperatorEffect {
                layer,
                operator: "attention",
                r_in: mean_bias_ratio(xin),
                r_out: mean_bias_ratio(xout),
                mean_cos: cosine(&xin.col_mean(), &xout.col_mean()),
            });
        }
        if let (Some(xin), Some(xout)) =
            (taps.get(layer, TapStage::FfnInput), taps.get(layer, TapStage::FfnOutput))
        {
            out.push(OperatorEffect {
                layer,
                operator: "ffn",
                r_in: mean_bias_ratio(xin),
                r_out: mean_bias_ratio(xout),
                mean_cos: cosine(&xin.col_mean(), &xout.col_mean()),
            });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{ModelConfig, Params, Transformer};
    use crate::quant::QuantRecipe;
    use crate::tensor::Rng;

    fn run_taps() -> (Taps, usize) {
        let cfg = ModelConfig::test_tiny(64);
        let params = Params::init(&cfg, &mut Rng::new(220));
        let mut model = Transformer::new(cfg, QuantRecipe::Bf16, 0);
        let mut rng = Rng::new(221);
        let tokens: Vec<u32> = (0..32).map(|_| rng.below(64) as u32).collect();
        let mut taps = Taps::enabled();
        let _ = model.forward(&params, &tokens, 2, 16, &mut taps);
        (taps, cfg.n_layers)
    }

    #[test]
    fn trace_covers_all_stages() {
        let (taps, n) = run_taps();
        let trace = operator_trace(&taps, n);
        assert_eq!(trace.len(), n * TapStage::FORWARD_CHAIN.len());
        for p in &trace {
            assert!(p.ratio.is_finite() && p.ratio >= 0.0);
            assert!(p.mean_cos_prev.is_finite());
        }
    }

    #[test]
    fn effects_cover_both_operators() {
        let (taps, n) = run_taps();
        let fx = operator_effects(&taps, n);
        assert_eq!(fx.len(), 2 * n);
        assert!(fx.iter().any(|e| e.operator == "attention"));
        assert!(fx.iter().any(|e| e.operator == "ffn"));
    }
}
