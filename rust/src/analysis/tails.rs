//! Tail-contraction diagnostics (paper App. C): compare the high-magnitude
//! tail of raw activations vs mean-centered residuals.

use crate::tensor::ops::percentile;
use crate::tensor::Mat;

/// Tail summary of one sample.
#[derive(Clone, Copy, Debug)]
pub struct TailStats {
    pub amax: f32,
    pub p999: f32,
    pub p99: f32,
    /// fraction of entries with |x| > 4·rms (far-tail exceedance rate)
    pub far_tail_frac: f32,
}

pub fn tail_stats(xs: &[f32]) -> TailStats {
    let abs: Vec<f32> = xs.iter().map(|v| v.abs()).collect();
    let rms = (abs.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>() / abs.len() as f64)
        .sqrt() as f32;
    let thresh = 4.0 * rms;
    let far = abs.iter().filter(|&&v| v > thresh).count() as f32 / abs.len() as f32;
    TailStats {
        amax: abs.iter().fold(0.0f32, |a, &b| a.max(b)),
        p999: percentile(&abs, 99.9),
        p99: percentile(&abs, 99.0),
        far_tail_frac: far,
    }
}

/// App.-C comparison: (raw tail, residual tail) for one activation matrix.
pub fn raw_vs_residual_tails(x: &Mat) -> (TailStats, TailStats) {
    let raw = tail_stats(&x.data);
    let mu = x.col_mean();
    let mut r = x.clone();
    r.sub_row_vec(&mu);
    let res = tail_stats(&r.data);
    (raw, res)
}

/// Dynamic-range proxy the quantizer cares about: amax / median|x|.
pub fn dynamic_range(xs: &[f32]) -> f32 {
    let abs: Vec<f32> = xs.iter().map(|v| v.abs()).collect();
    let med = percentile(&abs, 50.0).max(1e-12);
    abs.iter().fold(0.0f32, |a, &b| a.max(b)) / med
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    #[test]
    fn mean_removal_contracts_tail_on_biased_data() {
        let mut rng = Rng::new(200);
        let mut x = Mat::randn(512, 128, 0.5, &mut rng);
        let mut mu = vec![0.0f32; 128];
        for j in (0..128).step_by(10) {
            mu[j] = 8.0;
        }
        x.add_row_vec(&mu);
        let (raw, res) = raw_vs_residual_tails(&x);
        assert!(res.amax < 0.5 * raw.amax, "amax {} → {}", raw.amax, res.amax);
        assert!(res.p999 < 0.5 * raw.p999);
    }

    #[test]
    fn centered_data_unchanged() {
        let mut rng = Rng::new(201);
        let mut x = Mat::randn(256, 64, 1.0, &mut rng);
        let mu = x.col_mean();
        x.sub_row_vec(&mu);
        let (raw, res) = raw_vs_residual_tails(&x);
        assert!((raw.amax - res.amax).abs() / raw.amax < 0.05);
    }

    #[test]
    fn dynamic_range_detects_outliers() {
        let mut v = vec![1.0f32; 100];
        let dr_flat = dynamic_range(&v);
        v[0] = 100.0;
        let dr_spiky = dynamic_range(&v);
        assert!(dr_spiky > 50.0 * dr_flat);
    }
}
