//! Theorem-1 validation (paper §2.3 + App. E): the exact two-sided Gaussian
//! tail formula (Eq. 4), its far-tail one-sided asymptotic (Eq. 6), and the
//! amplification ratio vs the zero-mean baseline (Eq. 7), checked both in
//! closed form and by Monte-Carlo on the Gaussian row-sampling model.

use crate::linalg::gaussian::{log_q, q_function};
use crate::tensor::Rng;

/// Eq. (4): P(|Y| > t) for Y ~ N(m, τ²).
pub fn exact_two_sided_tail(t: f64, m: f64, tau: f64) -> f64 {
    q_function((t - m.abs()) / tau) + q_function((t + m.abs()) / tau)
}

/// Eq. (6): far-tail one-sided approximation Q((t−|m|)/τ).
pub fn one_sided_tail(t: f64, m: f64, tau: f64) -> f64 {
    q_function((t - m.abs()) / tau)
}

/// Eq. (7): predicted amplification ratio P(|Y|>t) / P(|Y⁰|>t) with
/// Y⁰ ~ N(0, τ²), in log space for far tails:
///   log ratio ≈ log(t / (2(t−|m|))) + (2t|m| − m²) / (2τ²).
pub fn log_amplification_eq7(t: f64, m: f64, tau: f64) -> f64 {
    let m = m.abs();
    assert!(t > m, "Eq. 7 requires t > |m|");
    (t / (2.0 * (t - m))).ln() + (2.0 * t * m - m * m) / (2.0 * tau * tau)
}

/// Exact log amplification from the tail formulas (for validating Eq. 7).
pub fn log_amplification_exact(t: f64, m: f64, tau: f64) -> f64 {
    let num = exact_two_sided_tail(t, m, tau).max(f64::MIN_POSITIVE).ln();
    // baseline 2Q(t/τ) via log_q for far tails
    let den = (2.0f64).ln() + log_q(t / tau);
    num - den
}

/// Monte-Carlo estimate of P(|Y| > t) with Y = m + τ·Z.
pub fn monte_carlo_tail(t: f64, m: f64, tau: f64, n: usize, rng: &mut Rng) -> f64 {
    let mut hits = 0usize;
    for _ in 0..n {
        let y = m + tau * rng.normal() as f64;
        if y.abs() > t {
            hits += 1;
        }
    }
    hits as f64 / n as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eq4_matches_monte_carlo() {
        let mut rng = Rng::new(210);
        for &(t, m, tau) in &[(2.0, 1.0, 1.0), (3.0, 2.0, 0.8), (1.5, 0.0, 1.0)] {
            let exact = exact_two_sided_tail(t, m, tau);
            let mc = monte_carlo_tail(t, m, tau, 400_000, &mut rng);
            assert!(
                (exact - mc).abs() < 5e-3 + 0.05 * exact,
                "t={t} m={m} τ={tau}: exact {exact} mc {mc}"
            );
        }
    }

    #[test]
    fn eq6_one_sided_dominates_in_far_tail() {
        // Q((t+|m|)/τ) must become negligible vs Q((t−|m|)/τ)
        let (m, tau) = (3.0, 0.5);
        for &t in &[4.0, 5.0, 6.0] {
            let two = exact_two_sided_tail(t, m, tau);
            let one = one_sided_tail(t, m, tau);
            assert!((two - one).abs() / one < 1e-6, "t={t}: {two} vs {one}");
        }
    }

    #[test]
    fn eq7_matches_exact_log_ratio_asymptotically() {
        // the approximation tightens as (t−|m|)/τ and t|m|/τ² grow
        let (m, tau) = (2.0, 0.4);
        let mut prev_err = f64::INFINITY;
        for &t in &[3.0, 4.0, 5.0] {
            let approx = log_amplification_eq7(t, m, tau);
            let exact = log_amplification_exact(t, m, tau);
            let rel = (approx - exact).abs() / exact.abs();
            assert!(rel < 0.1, "t={t}: approx {approx} exact {exact}");
            assert!(rel <= prev_err + 1e-9, "error should shrink with t");
            prev_err = rel;
        }
    }

    #[test]
    fn amplification_is_exponential_in_mean() {
        // the paper's core claim: amplification grows exponentially with |m|
        let (t, tau) = (5.0, 0.5);
        let a1 = log_amplification_eq7(t, 1.0, tau);
        let a2 = log_amplification_eq7(t, 2.0, tau);
        let a3 = log_amplification_eq7(t, 3.0, tau);
        // log-ratio grows ~linearly in m ⇒ ratio exponential
        assert!(a2 - a1 > 5.0);
        assert!(a3 - a2 > 5.0);
    }

    #[test]
    fn zero_mean_gives_no_amplification() {
        let la = log_amplification_exact(4.0, 0.0, 1.0);
        assert!(la.abs() < 1e-6, "zero mean should give ratio 1, log {la}");
    }

    #[test]
    fn mc_confirms_amplification_in_reachable_regime() {
        // in a regime where MC can resolve both tails
        let mut rng = Rng::new(211);
        let (t, tau) = (2.5, 1.0);
        let p_biased = monte_carlo_tail(t, 1.5, tau, 400_000, &mut rng);
        let p_zero = monte_carlo_tail(t, 0.0, tau, 400_000, &mut rng);
        let mc_ratio = p_biased / p_zero;
        let predicted = (log_amplification_exact(t, 1.5, tau)).exp();
        assert!(
            (mc_ratio - predicted).abs() / predicted < 0.15,
            "mc {mc_ratio} vs predicted {predicted}"
        );
    }
}
