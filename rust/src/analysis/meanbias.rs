//! Mean-bias diagnostics (paper §2.1–2.2, Figs. 1 & 2).

use crate::linalg::{top_k_svd, Svd};
use crate::tensor::ops::cosine;
use crate::tensor::{Mat, Rng};

/// Normalized mean-bias ratio  R = ‖μ_X‖₂ / √(‖X‖_F² / l)  (paper §2.2).
/// R ∈ [0, 1]; R² is the fraction of the matrix's mean-square energy carried
/// by the rank-one mean component.
pub fn mean_bias_ratio(x: &Mat) -> f32 {
    let mu = x.col_mean();
    let mu_norm = crate::tensor::ops::l2_norm(&mu);
    let rms = (x.fro_norm().powi(2) / x.rows as f32).sqrt();
    if rms == 0.0 {
        0.0
    } else {
        mu_norm / rms
    }
}

/// Full Fig.-1-style report for one activation matrix.
#[derive(Clone, Debug)]
pub struct MeanBiasReport {
    /// top singular values (spectrum head, Fig. 1A)
    pub top_singular_values: Vec<f32>,
    /// R ratio
    pub ratio: f32,
    /// |cos(μ, v_k)| for the top-k right singular vectors (Fig. 1C)
    pub mu_vk_cos: Vec<f32>,
    /// cos(u₁, e) alignment of the leading left vector with all-ones (β₁)
    pub beta1: f32,
    /// token-wise cosine similarities with the mean direction (Fig. 1B)
    pub token_cos_mean: Vec<f32>,
    /// token-wise cosine similarities with v₂ (the non-mean direction)
    pub token_cos_v2: Vec<f32>,
}

/// Compute the report using a top-k truncated SVD (k small).
pub fn mean_bias_report(x: &Mat, k: usize, rng: &mut Rng) -> MeanBiasReport {
    let svd = top_k_svd(x, k.max(2), 35, rng);
    report_from_svd(x, &svd)
}

/// Report from a precomputed SVD (lets callers reuse the factorization).
pub fn report_from_svd(x: &Mat, svd: &Svd) -> MeanBiasReport {
    let mu = x.col_mean();
    let k = svd.s.len();
    let mu_vk_cos: Vec<f32> = (0..k)
        .map(|t| {
            let vk: Vec<f32> = (0..x.cols).map(|j| svd.v.at(j, t)).collect();
            cosine(&mu, &vk).abs()
        })
        .collect();
    // β₁ = <u₁, 1/√l>
    let l = x.rows;
    let beta1 = (0..l).map(|i| svd.u.at(i, 0)).sum::<f32>() / (l as f32).sqrt();
    // token-wise cosines
    let v2: Vec<f32> = (0..x.cols).map(|j| svd.v.at(j, 1.min(k - 1))).collect();
    let mut token_cos_mean = Vec::with_capacity(l);
    let mut token_cos_v2 = Vec::with_capacity(l);
    for i in 0..l {
        token_cos_mean.push(cosine(x.row(i), &mu));
        token_cos_v2.push(cosine(x.row(i), &v2));
    }
    MeanBiasReport {
        top_singular_values: svd.s.clone(),
        ratio: mean_bias_ratio(x),
        mu_vk_cos,
        beta1: beta1.abs(),
        token_cos_mean,
        token_cos_v2,
    }
}

/// Fraction of tokens whose cosine with the mean direction is positive —
/// the "one-sidedness" summary of Fig. 1B.
pub fn one_sidedness(report: &MeanBiasReport) -> f32 {
    let n = report.token_cos_mean.len();
    if n == 0 {
        return 0.0;
    }
    report.token_cos_mean.iter().filter(|&&c| c > 0.0).count() as f32 / n as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mean_biased(l: usize, m: usize, bias: f32, noise: f32, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        let mut x = Mat::randn(l, m, noise, &mut rng);
        let mu = Mat::randn(1, m, bias, &mut rng);
        x.add_row_vec(&mu.data);
        x
    }

    #[test]
    fn ratio_zero_for_centered() {
        let mut rng = Rng::new(160);
        let mut x = Mat::randn(64, 32, 1.0, &mut rng);
        let mu = x.col_mean();
        x.sub_row_vec(&mu);
        assert!(mean_bias_ratio(&x) < 1e-5);
    }

    #[test]
    fn ratio_one_for_pure_mean() {
        // X = 1·μᵀ exactly ⇒ R = 1
        let mu = vec![1.0f32, -2.0, 0.5, 3.0];
        let mut x = Mat::zeros(16, 4);
        x.add_row_vec(&mu);
        assert!((mean_bias_ratio(&x) - 1.0).abs() < 1e-5);
    }

    #[test]
    fn ratio_increases_with_bias() {
        let low = mean_bias_ratio(&mean_biased(128, 64, 0.2, 1.0, 161));
        let high = mean_bias_ratio(&mean_biased(128, 64, 3.0, 1.0, 161));
        assert!(high > low + 0.2, "low {low} high {high}");
    }

    #[test]
    fn report_on_biased_data_matches_paper_phenomenology() {
        let x = mean_biased(256, 96, 2.5, 0.5, 162);
        let mut rng = Rng::new(163);
        let rep = mean_bias_report(&x, 4, &mut rng);
        // μ aligns with v1 far more than with later directions (Fig. 1C)
        assert!(rep.mu_vk_cos[0] > 0.95, "mu-v1 cos {}", rep.mu_vk_cos[0]);
        assert!(rep.mu_vk_cos[0] > 2.0 * rep.mu_vk_cos[1]);
        // leading left vector aligns with all-ones (β₁ large)
        assert!(rep.beta1 > 0.9, "beta1 {}", rep.beta1);
        // tokens are one-sided along the mean direction (Fig. 1B)
        assert!(one_sidedness(&rep) > 0.95);
        // dominant spectral spike (Fig. 1A)
        assert!(rep.top_singular_values[0] > 3.0 * rep.top_singular_values[1]);
    }

    #[test]
    fn unbiased_data_is_far_less_one_sided_than_biased() {
        // raw iid Gaussian data has a small positive one-sidedness bias
        // (each token contributes 1/l of the empirical mean), but it must be
        // far below the near-unanimous alignment of biased data
        let mut rng = Rng::new(164);
        let x = Mat::randn(128, 48, 1.0, &mut rng);
        let mut r2 = Rng::new(165);
        let rep = mean_bias_report(&x, 3, &mut r2);
        let os_unbiased = one_sidedness(&rep);
        let xb = mean_biased(128, 48, 2.5, 0.5, 166);
        let mut r3 = Rng::new(167);
        let os_biased = one_sidedness(&mean_bias_report(&xb, 3, &mut r3));
        assert!(os_unbiased < 0.9, "unbiased one-sidedness {os_unbiased}");
        assert!(os_biased > 0.97, "biased one-sidedness {os_biased}");
        assert!(os_biased > os_unbiased);
    }
}
