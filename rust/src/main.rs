//! `averis` — CLI entrypoint of the L3 coordinator.
//!
//! See `averis help` (config::cli::USAGE) for commands; DESIGN.md §5 maps
//! each paper table/figure to its driver.

use anyhow::{bail, Context, Result};
use averis::bench_harness::record_markdown_block;
use averis::config::cli::{CliArgs, Command, USAGE};
use averis::config::{apply_overrides, ConfigFile, ExperimentConfig, ModelPreset};
use averis::coordinator::{
    evaluate_probes, figures, pjrt_train_run, sim_train_run, sim_train_run_with,
    train_options_for, RunDir,
};
use averis::coordinator::probe_eval::mean_accuracy;
use averis::data::{Corpus, CorpusConfig};
use averis::metrics::CsvSink;
use averis::model::Params;
use averis::quant::averis::split_vs_plain_error;
use averis::quant::{Nvfp4Quantizer, QuantRecipe};
use averis::runtime::{save_params_checkpoint, ArtifactStore};
use averis::serve::{
    bench_cache_churn, bench_continuous_decode, measure_calib_means, CalibMeans, ChurnShape,
    Daemon, DaemonConfig, Engine, EngineConfig, FaultPlan, KvBackendCfg, QuantizedCheckpoint,
    SampleCfg,
};
use averis::tensor::{parallel, Mat, Rng};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = match CliArgs::parse(&argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{USAGE}");
            std::process::exit(2);
        }
    };
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Build an ExperimentConfig from CLI flags (+ optional --config file).
fn experiment_from_args(args: &CliArgs) -> Result<ExperimentConfig> {
    let preset = ModelPreset::parse(&args.get_or("model", "dense")).map_err(anyhow::Error::msg)?;
    let recipe: QuantRecipe =
        args.get_or("recipe", "averis").parse().map_err(anyhow::Error::msg)?;
    let mut exp = ExperimentConfig::defaults(preset, recipe);
    if let Some(path) = args.get("config") {
        let file = ConfigFile::load(path).map_err(anyhow::Error::msg)?;
        apply_overrides(&mut exp, &file).map_err(anyhow::Error::msg)?;
    }
    if let Some(v) = args.get_parse::<u64>("steps").map_err(anyhow::Error::msg)? {
        exp.train.steps = v;
    }
    if let Some(v) = args.get_parse::<usize>("batch").map_err(anyhow::Error::msg)? {
        exp.train.batch = v;
    }
    if let Some(v) = args.get_parse::<usize>("seq").map_err(anyhow::Error::msg)? {
        exp.train.seq = v;
    }
    if let Some(v) = args.get_parse::<u64>("seed").map_err(anyhow::Error::msg)? {
        exp.train.seed = v;
    }
    if let Some(v) = args.get_parse::<usize>("threads").map_err(anyhow::Error::msg)? {
        exp.train.threads = v;
    }
    if let Some(v) = args.get_parse::<u64>("corpus-seed").map_err(anyhow::Error::msg)? {
        exp.corpus_seed = v;
    }
    if let Some(v) = args.get("out") {
        exp.out_dir = v.to_string();
    }
    if let Some(v) = args.get("telemetry") {
        // bare `--telemetry` parses as "true": use the default path
        exp.telemetry = Some(if v == "true" {
            averis::telemetry::DEFAULT_PATH.to_string()
        } else {
            v.to_string()
        });
    }
    if let Some(v) = args.get_parse::<u32>("telemetry-stride").map_err(anyhow::Error::msg)? {
        exp.telemetry_stride = v;
    }
    if let Some(v) = args.get_parse::<u64>("checkpoint-every").map_err(anyhow::Error::msg)? {
        exp.checkpoint_every = v;
    }
    if let Some(v) = args.get("checkpoint-dir") {
        exp.checkpoint_dir = Some(v.to_string());
    }
    if let Some(v) = args.get_parse::<usize>("checkpoint-keep").map_err(anyhow::Error::msg)? {
        exp.checkpoint_keep = v;
    }
    if args.get("resume").is_some() {
        exp.resume = true;
    }
    Ok(exp)
}

/// Resolve the fault plan for training: `--faults kind:rate,...` (with
/// `--fault-seed N`) wins over the `AVERIS_FAULTS` environment.
fn fault_plan_from_args(args: &CliArgs) -> Result<FaultPlan> {
    if let Some(spec) = args.get("faults") {
        let seed =
            args.get_parse::<u64>("fault-seed").map_err(anyhow::Error::msg)?.unwrap_or(0);
        return FaultPlan::parse(spec, seed).map_err(anyhow::Error::msg);
    }
    FaultPlan::from_env().map_err(anyhow::Error::msg)
}

/// Apply a `--simd off|sse2|avx2` flag: force the kernel dispatch level,
/// clamped to hardware support. Applied before any command runs (and
/// before `parallel::install`, which respects a forced level), so every
/// kernel in the process sees it. Purely a perf/debug knob — every level
/// computes identical bits (DESIGN.md §9).
fn apply_simd_flag(args: &CliArgs) -> Result<()> {
    let Some(v) = args.get("simd") else {
        return Ok(());
    };
    let want = averis::quant::simd::parse_level(v)
        .with_context(|| format!("--simd: unknown level '{v}' (expected off|sse2|avx2)"))?;
    let got = averis::quant::simd::force(want);
    if got != want {
        eprintln!("--simd {v}: not supported on this CPU, degrading to {got}");
    }
    Ok(())
}

/// Apply `--telemetry [PATH]` / `--telemetry-stride N` before any command
/// runs, so every subsystem (train, generate, serve-bench) sees the layer
/// configured. A CLI flag wins over `AVERIS_TELEMETRY`: `enable` marks the
/// layer configured, which makes the env resolution in
/// `parallel::install` a no-op. Purely observational — recorded bits are
/// identical with telemetry on, off, or sampled.
fn apply_telemetry_flag(args: &CliArgs) -> Result<()> {
    if let Some(v) = args.get("telemetry") {
        let path = if v == "true" { averis::telemetry::DEFAULT_PATH } else { v };
        averis::telemetry::enable(path);
    }
    if let Some(n) = args.get_parse::<u32>("telemetry-stride").map_err(anyhow::Error::msg)? {
        averis::telemetry::set_stride(n);
    }
    Ok(())
}

fn run(args: &CliArgs) -> Result<()> {
    apply_simd_flag(args)?;
    apply_telemetry_flag(args)?;
    match args.command {
        Command::Help => {
            println!("{USAGE}");
            Ok(())
        }
        Command::Info => info(args),
        Command::QuantDemo => quant_demo(),
        Command::Train => train_cmd(args),
        Command::Analyze => analyze_cmd(args),
        Command::Fig6 => fig6_cmd(args),
        Command::Table1 => table1_cmd(args),
        Command::Generate => generate_cmd(args),
        Command::Serve => serve_cmd(args),
        Command::ServeBench => serve_bench_cmd(args),
        Command::ChurnBench => churn_bench_cmd(args),
        Command::TelemetryReport => telemetry_report_cmd(args),
    }
}

fn telemetry_report_cmd(args: &CliArgs) -> Result<()> {
    let path = args.get_or("file", averis::telemetry::DEFAULT_PATH);
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("reading telemetry stream {path}"))?;
    let report = averis::telemetry::report::render_report(&text).map_err(anyhow::Error::msg)?;
    print!("{report}");
    Ok(())
}

fn info(args: &CliArgs) -> Result<()> {
    println!("averis {} — FP4 mean-bias reproduction", env!("CARGO_PKG_VERSION"));
    let dir = args.get_or("artifacts", "artifacts");
    match ArtifactStore::open(&dir) {
        Ok(store) => {
            let m = &store.manifest;
            println!("artifacts: {dir}");
            println!(
                "  model: vocab={} d_model={} layers={} batch={} seq={}  ({} params)",
                m.vocab, m.d_model, m.n_layers, m.batch, m.seq, m.n_params
            );
            for r in QuantRecipe::PAPER_SET {
                let t = store.train_hlo(r).is_ok();
                let e = store.eval_hlo(r).is_ok();
                println!("  {r:<16} train={t} eval={e}");
            }
        }
        Err(e) => println!("artifacts: unavailable ({e}) — run `make artifacts`"),
    }
    Ok(())
}

fn quant_demo() -> Result<()> {
    println!("NVFP4 quantization error on synthetic activations (rel. L2):\n");
    let mut rng = Rng::new(42);
    let quant = Nvfp4Quantizer::nvfp4();
    for (name, bias, noise) in [
        ("centered Gaussian", 0.0f32, 1.0f32),
        ("mild mean bias", 2.0, 1.0),
        ("outlier columns (paper regime)", 8.0, 0.3),
    ] {
        let mut x = Mat::randn(512, 128, noise, &mut rng);
        let mut mu = vec![0.0f32; 128];
        for (j, v) in mu.iter_mut().enumerate() {
            if j % 16 == 3 {
                *v = bias;
            }
        }
        x.add_row_vec(&mu);
        let (plain, split) = split_vs_plain_error(&x, &quant);
        println!(
            "  {name:<32} vanilla {plain:.4}   averis-split {split:.4}   ({:.2}x)",
            plain / split.max(1e-9)
        );
    }
    Ok(())
}

fn train_cmd(args: &CliArgs) -> Result<()> {
    let exp = experiment_from_args(args)?;
    let engine = args.get_or("engine", "sim");
    match engine.as_str() {
        "sim" => {
            println!(
                "simulator training: {} / {} / {} steps",
                exp.preset.name(),
                exp.recipe,
                exp.train.steps
            );
            let mut opts = train_options_for(&exp);
            opts.faults = fault_plan_from_args(args)?;
            let r = sim_train_run_with(&exp, false, opts)?;
            println!(
                "final train loss (ema) {:.4}   heldout {:.4}   {:.2} s/step",
                r.final_train_loss, r.final_eval_loss, r.sec_per_step
            );
            // the CI kill-and-resume leg greps this line: a resumed run must
            // print the same checksum as an uninterrupted one
            println!(
                "loss-curve checksum {:#010x} ({} points)",
                averis::train::loss_curve_checksum(&r.loss_curve),
                r.loss_curve.len()
            );
            if let Some(step) = r.report.resumed_from {
                println!("resumed from step {step}");
            }
            if !r.report.interventions.is_empty() {
                println!(
                    "sentinel: {} skipped, {} rollbacks, {} escalations, final recipe {}",
                    r.report.skipped_steps,
                    r.report.rollbacks,
                    r.report.escalations,
                    r.final_recipe
                );
            }
            if args.get("save").is_some() || args.get("save-quant").is_some() {
                let (calib, cfg) = calibrate_from_corpus(&exp, &r.params);
                if let Some(path) = args.get("save") {
                    save_params_checkpoint(path, &cfg, &r.params, &calib)?;
                    println!("saved f32 checkpoint + calibration means to {path}");
                }
                if let Some(path) = args.get("save-quant") {
                    let ckpt = QuantizedCheckpoint::build(&cfg, &r.params, &calib);
                    ckpt.save(path)?;
                    println!(
                        "saved packed serving checkpoint to {path} ({} KiB packed)",
                        ckpt.storage_bytes() / 1024
                    );
                }
            }
        }
        "pjrt" => {
            if exp.preset.is_moe() {
                bail!("PJRT artifacts cover the dense model; use --engine sim for MoE");
            }
            if args.get("save").is_some() || args.get("save-quant").is_some() {
                bail!("--save/--save-quant need the structured Params of the sim engine; \
                       rerun with --engine sim");
            }
            let store = ArtifactStore::open(args.get_or("artifacts", "artifacts"))?;
            let client = xla::PjRtClient::cpu()?;
            println!(
                "PJRT training on {} ({} devices): {} / {} steps",
                client.platform_name(),
                client.device_count(),
                exp.recipe,
                exp.train.steps
            );
            let run = RunDir::create(&exp.out_dir, &format!("pjrt_{}", exp.run_name()))?;
            let r = pjrt_train_run(
                &client,
                &store,
                exp.recipe,
                exp.train.steps,
                exp.train.seed,
                exp.corpus_seed,
                &run.path,
            )?;
            println!(
                "final loss {:.4}   heldout(eval-quantized) {:.4}   {:.3} s/step",
                r.loss_curve.last().map(|&(_, l)| l).unwrap_or(f32::NAN),
                r.final_eval_loss,
                r.sec_per_step
            );
        }
        other => bail!("unknown engine '{other}' (sim|pjrt)"),
    }
    Ok(())
}

/// Capture frozen calibration means for serving: one full-precision forward
/// over a deterministic batch of the training corpus (the serve path
/// conditions its Averis split on these where the token-mean degenerates).
fn calibrate_from_corpus(
    exp: &ExperimentConfig,
    params: &Params,
) -> (CalibMeans, averis::model::ModelConfig) {
    let cfg = exp.model_config();
    // deterministic regeneration of exactly the corpus sim_train_run trained
    // on (same (exp.corpus, exp.corpus_seed) inputs) — a few ms of redundant
    // work, accepted to keep sim_train_run's signature corpus-free
    let corpus = Corpus::generate(exp.corpus, exp.corpus_seed);
    let (batch, seq) = (exp.train.batch, exp.train.seq);
    let need = batch * seq;
    let tokens: Vec<u32> = corpus.train.iter().copied().cycle().take(need).collect();
    (measure_calib_means(&cfg, params, &tokens, batch, seq), cfg)
}

fn generate_cmd(args: &CliArgs) -> Result<()> {
    let path = args.get("ckpt").context("generate needs --ckpt FILE")?;
    if let Some(t) = args.get_parse::<usize>("threads").map_err(anyhow::Error::msg)? {
        // sizes the persistent worker pool once; the kernels never spawn
        // per call after this
        parallel::install(t);
    }
    let ckpt = QuantizedCheckpoint::load_any(path)?;
    let vocab = ckpt.cfg.vocab;
    let seed = args.get_parse::<u64>("seed").map_err(anyhow::Error::msg)?.unwrap_or(0);
    let max_new = args.get_parse::<usize>("max-new").map_err(anyhow::Error::msg)?.unwrap_or(32);
    let sampler = match args.get_parse::<usize>("top-k").map_err(anyhow::Error::msg)? {
        None | Some(0) => SampleCfg::Greedy,
        Some(k) => SampleCfg::TopK {
            k,
            temperature: args
                .get_parse::<f32>("temperature")
                .map_err(anyhow::Error::msg)?
                .unwrap_or(1.0),
        },
    };
    let prompt: Vec<u32> = match args.get("prompt") {
        Some(s) => {
            let toks: Vec<u32> = s
                .split(|c: char| c == ',' || c.is_whitespace())
                .filter(|t| !t.is_empty())
                .map(|t| t.parse::<u32>().map_err(|e| anyhow::anyhow!("--prompt: {e}")))
                .collect::<Result<_>>()?;
            toks
        }
        None => {
            let len = args
                .get_parse::<usize>("prompt-len")
                .map_err(anyhow::Error::msg)?
                .unwrap_or(16);
            let mut rng = Rng::new(seed ^ 0x9E37);
            (0..len.max(1)).map(|_| rng.below(vocab) as u32).collect()
        }
    };
    println!(
        "model: d={} layers={} vocab={}   packed weights: {} KiB",
        ckpt.cfg.d_model,
        ckpt.cfg.n_layers,
        vocab,
        ckpt.storage_bytes() / 1024
    );
    let mut engine = Engine::new(ckpt, 1, seed);
    engine.submit(prompt.clone(), max_new, sampler, None)?;
    let t0 = std::time::Instant::now();
    let done = engine.run();
    let wall = t0.elapsed().as_secs_f64();
    let toks = &done[0].tokens;
    println!("prompt    : {prompt:?}");
    println!("generated : {toks:?}");
    println!(
        "{} tokens in {:.3} s  ({:.1} tok/s, KV-cached packed decode)",
        toks.len(),
        wall,
        toks.len() as f64 / wall.max(1e-9)
    );
    Ok(())
}

/// `averis serve` — run the HTTP daemon until SIGINT/SIGTERM or
/// `POST /v1/shutdown`, then drain gracefully and report.
fn serve_cmd(args: &CliArgs) -> Result<()> {
    if let Some(t) = args.get_parse::<usize>("threads").map_err(anyhow::Error::msg)? {
        parallel::install(t);
    }
    let seed = args.get_parse::<u64>("seed").map_err(anyhow::Error::msg)?.unwrap_or(0);
    let ckpt = match args.get("ckpt") {
        Some(path) => QuantizedCheckpoint::load_any(path)?,
        None => {
            // no checkpoint: synthesize deterministic weights so the daemon
            // (and its CI smoke leg) runs self-contained
            let preset =
                ModelPreset::parse(&args.get_or("model", "tiny")).map_err(anyhow::Error::msg)?;
            let cfg = preset.model_config(256);
            let params = Params::init(&cfg, &mut Rng::new(seed));
            let calib = CalibMeans::zeros(cfg.n_layers, cfg.d_model);
            QuantizedCheckpoint::build(&cfg, &params, &calib)
        }
    };
    println!(
        "serve: model d={} layers={} vocab={} ({} KiB packed)",
        ckpt.cfg.d_model,
        ckpt.cfg.n_layers,
        ckpt.cfg.vocab,
        ckpt.storage_bytes() / 1024
    );
    let max_active = args.get_parse::<usize>("max-active").map_err(anyhow::Error::msg)?.unwrap_or(8);
    let kv = KvBackendCfg::Paged {
        block_tokens: args
            .get_parse::<usize>("kv-block")
            .map_err(anyhow::Error::msg)?
            .unwrap_or(32),
        budget_tokens: args
            .get_parse::<usize>("kv-budget")
            .map_err(anyhow::Error::msg)?
            .filter(|&b| b > 0),
        prefix_share: true,
        swap_dir: args.get("swap-dir").map(std::path::PathBuf::from),
    };
    let mut engine = Engine::with_config(ckpt, EngineConfig { max_active, seed, kv });
    if let Some(spec) = args.get("faults") {
        let fault_seed =
            args.get_parse::<u64>("fault-seed").map_err(anyhow::Error::msg)?.unwrap_or(0);
        let mut plan = FaultPlan::parse(spec, fault_seed).map_err(anyhow::Error::msg)?;
        if let Some(stall) = args.get_parse::<u64>("stall-ms").map_err(anyhow::Error::msg)? {
            plan.set_stall_ms(stall);
        }
        println!("serve: fault injection armed: {}", plan.spec());
        engine.set_faults(plan);
    }
    let addr = match (args.get("addr"), args.get_parse::<u16>("port").map_err(anyhow::Error::msg)?)
    {
        (Some(a), _) => a.to_string(),
        (None, Some(p)) => format!("127.0.0.1:{p}"),
        (None, None) => "127.0.0.1:8417".to_string(),
    };
    let dcfg = DaemonConfig {
        addr,
        queue_cap: args.get_parse("queue-cap").map_err(anyhow::Error::msg)?.unwrap_or(64),
        kv_watermark: args.get_parse("kv-watermark").map_err(anyhow::Error::msg)?.unwrap_or(0.9),
        default_max_new: args.get_parse("max-new").map_err(anyhow::Error::msg)?.unwrap_or(16),
        deadline_ms: args.get_parse("deadline-ms").map_err(anyhow::Error::msg)?.unwrap_or(0),
        idle_timeout_ms: args
            .get_parse("idle-timeout-ms")
            .map_err(anyhow::Error::msg)?
            .unwrap_or(5000),
        drain_timeout_ms: args
            .get_parse("drain-timeout-ms")
            .map_err(anyhow::Error::msg)?
            .unwrap_or(10_000),
    };
    let (queue_cap, watermark) = (dcfg.queue_cap, dcfg.kv_watermark);
    let daemon = Daemon::spawn(engine, dcfg)?;
    println!(
        "serve: listening on {} (max_active={max_active}, queue_cap={queue_cap}, \
         kv_watermark={watermark:.2}, {} threads)",
        daemon.addr(),
        parallel::threads()
    );
    sig::install();
    while !sig::requested() && !daemon.shutdown_requested() {
        std::thread::sleep(std::time::Duration::from_millis(100));
    }
    println!("serve: shutdown requested, draining in-flight sessions...");
    let r = daemon.shutdown();
    println!(
        "serve: accepted={} completed={} rejected_429={} rejected_4xx={} deadline_cancels={} \
         disconnect_cancels={} shutdown_cancels={}",
        r.accepted,
        r.completed,
        r.rejected_429,
        r.rejected_4xx,
        r.deadline_cancels,
        r.disconnect_cancels,
        r.shutdown_cancels
    );
    println!(
        "serve: engine steps={} generated={} swap_outs={} swap_ins={} swap_recoveries={} \
         preemptions={} cancels={} stale_swaps_reclaimed={}",
        r.stats.steps,
        r.stats.generated_tokens,
        r.stats.swap_outs,
        r.stats.swap_ins,
        r.stats.swap_recoveries,
        r.stats.preemptions,
        r.stats.cancels,
        r.stats.stale_swaps_reclaimed
    );
    if r.drained_clean {
        println!("serve: drained clean (0 KV blocks leaked)");
    } else {
        println!(
            "serve: drain incomplete: {} KV blocks still allocated after quiesce",
            r.blocks_after_drain
        );
    }
    Ok(())
}

fn serve_bench_cmd(args: &CliArgs) -> Result<()> {
    let preset = ModelPreset::parse(&args.get_or("model", "dense")).map_err(anyhow::Error::msg)?;
    if let Some(t) = args.get_parse::<usize>("threads").map_err(anyhow::Error::msg)? {
        // sizes the persistent worker pool once for the whole bench
        parallel::install(t);
    }
    let batches: Vec<usize> = args
        .get_or("batches", "1,8,32")
        .split(',')
        .filter(|s| !s.is_empty())
        .map(|s| s.trim().parse::<usize>().map_err(|e| anyhow::anyhow!("--batches: {e}")))
        .collect::<Result<_>>()?;
    let seed = args.get_parse::<u64>("seed").map_err(anyhow::Error::msg)?.unwrap_or(42);
    let n_prompts = args.get_parse::<usize>("prompts").map_err(anyhow::Error::msg)?.unwrap_or(32);
    let prompt_len =
        args.get_parse::<usize>("prompt-len").map_err(anyhow::Error::msg)?.unwrap_or(16);
    let max_new = args.get_parse::<usize>("max-new").map_err(anyhow::Error::msg)?.unwrap_or(32);
    let cfg = preset.model_config(256);
    if prompt_len + max_new > cfg.max_seq {
        bail!(
            "--prompt-len {prompt_len} + --max-new {max_new} exceeds the {} preset's max_seq {}",
            preset.name(),
            cfg.max_seq
        );
    }
    let params = Params::init(&cfg, &mut Rng::new(seed));
    let calib = CalibMeans::zeros(cfg.n_layers, cfg.d_model);
    println!(
        "serve-bench: {} — {} prompts × (prefill {prompt_len} + decode {max_new}), batches {:?}, {} threads",
        preset.name(),
        n_prompts,
        batches,
        parallel::threads()
    );
    let rows = bench_continuous_decode(
        &cfg, &params, &calib, &batches, n_prompts, prompt_len, max_new, seed,
    );
    println!(
        "{:>10} {:>10} {:>10} {:>10} {:>12} {:>9} {:>10} {:>13} {:>10} {:>10}",
        "max_active",
        "sessions",
        "tokens",
        "wall_s",
        "tok/s",
        "queue_hw",
        "occupancy",
        "dec tok/step",
        "blocks_hw",
        "prefix_hit"
    );
    let mut md = String::from(
        "| max_active | sessions | decode tokens | wall (s) | tokens/sec | queue HW | \
         mean occupancy | decode tok/step | blocks HW | prefix hit | vs sequential |\n\
         |-----------:|---------:|--------------:|---------:|-----------:|---------:|\
         ---------------:|----------------:|----------:|-----------:|--------------:|\n",
    );
    // "vs sequential" only means something against the max_active = 1 row
    let base_tps = rows.iter().find(|r| r.max_active == 1).map(|r| r.tok_per_s);
    for r in &rows {
        println!(
            "{:>10} {:>10} {:>10} {:>10.3} {:>12.1} {:>9} {:>10.2} {:>13.2} {:>10} {:>9.1}%",
            r.max_active,
            r.sessions,
            r.generated,
            r.wall_s,
            r.tok_per_s,
            r.queue_high_water,
            r.mean_occupancy,
            r.decode_tok_per_step,
            r.blocks_high_water,
            r.prefix_hit_rate * 100.0
        );
        let vs_seq = match base_tps {
            Some(b) => format!("{:.2}x", r.tok_per_s / b),
            None => "n/a".to_string(),
        };
        md.push_str(&format!(
            "| {} | {} | {} | {:.3} | {:.1} | {} | {:.2} | {:.2} | {} | {:.1}% | {vs_seq} |\n",
            r.max_active,
            r.sessions,
            r.generated,
            r.wall_s,
            r.tok_per_s,
            r.queue_high_water,
            r.mean_occupancy,
            r.decode_tok_per_step,
            r.blocks_high_water,
            r.prefix_hit_rate * 100.0
        ));
    }
    md.push_str(&format!(
        "\nProtocol: `averis serve-bench --model {} --batches {} --prompts {n_prompts} \
         --prompt-len {prompt_len} --max-new {max_new} --seed {seed} --threads {}` \
         (greedy decoding; identical token streams at every batch size).",
        args.get_or("model", "dense"),
        args.get_or("batches", "1,8,32"),
        parallel::threads()
    ));
    let run = RunDir::create(&args.get_or("out", "runs"), "serve_bench")?;
    let mut csv = CsvSink::create(
        run.file("serve_bench.csv"),
        &[
            "max_active",
            "sessions",
            "tokens",
            "wall_s",
            "tok_per_s",
            "queue_high_water",
            "mean_occupancy",
            "decode_tok_per_step",
            "blocks_high_water",
            "prefix_hit_rate",
        ],
    )?;
    for r in &rows {
        csv.row(&[
            r.max_active as f64,
            r.sessions as f64,
            r.generated as f64,
            r.wall_s,
            r.tok_per_s,
            r.queue_high_water as f64,
            r.mean_occupancy,
            r.decode_tok_per_step,
            r.blocks_high_water as f64,
            r.prefix_hit_rate,
        ])?;
    }
    println!("csv written to {}", run.file("serve_bench.csv").display());
    if let Some(record) = args.get("record") {
        record_markdown_block(record, "serve-bench", &md)?;
        println!("recorded throughput table into {record}");
    }
    Ok(())
}

fn churn_bench_cmd(args: &CliArgs) -> Result<()> {
    let preset = ModelPreset::parse(&args.get_or("model", "dense")).map_err(anyhow::Error::msg)?;
    if let Some(t) = args.get_parse::<usize>("threads").map_err(anyhow::Error::msg)? {
        parallel::install(t);
    }
    let smoke = args.get("smoke").is_some();
    let mut shape = if smoke { ChurnShape::smoke() } else { ChurnShape::full() };
    if let Some(s) = args.get_parse::<u64>("seed").map_err(anyhow::Error::msg)? {
        shape.seed = s;
    }
    let cfg = preset.model_config(256);
    let params = Params::init(&cfg, &mut Rng::new(shape.seed));
    let calib = CalibMeans::zeros(cfg.n_layers, cfg.d_model);
    println!(
        "churn-bench: {} — {} sessions × {} turns, shared prefix {} + unique {} tokens, \
         {} new tokens/turn, KV budget {} rows/layer (block {}), cap {}, {} threads{}",
        preset.name(),
        shape.sessions,
        shape.turns,
        shape.system_prompt,
        shape.unique_prompt,
        shape.max_new,
        shape.budget_tokens,
        shape.block_tokens,
        shape.max_active,
        parallel::threads(),
        if smoke { " [smoke]" } else { "" }
    );
    let rows = bench_cache_churn(&cfg, &params, &calib, &shape);
    println!(
        "{:>8} {:>10} {:>10} {:>9} {:>8} {:>8} {:>8} {:>10} {:>10} {:>10} {:>12}",
        "backend",
        "live_peak",
        "turns",
        "prefill",
        "preempt",
        "swap_out",
        "swap_in",
        "prefix_hit",
        "blocks_hw",
        "wall_s",
        "tok/s"
    );
    let mut md = String::from(
        "| backend | peak live sessions | turns served | prefill tokens | preemptions | \
         swap-outs | swap-ins | prefix hit | blocks HW | wall (s) | tokens/sec | checksum |\n\
         |--------:|-------------------:|-------------:|---------------:|------------:|\
         ----------:|---------:|-----------:|----------:|---------:|-----------:|---------:|\n",
    );
    for r in &rows {
        println!(
            "{:>8} {:>10} {:>10} {:>9} {:>8} {:>8} {:>8} {:>9.1}% {:>10} {:>10.3} {:>12.1}",
            r.backend,
            r.peak_live_sessions,
            r.completed_turns,
            r.prefill_tokens,
            r.preemptions,
            r.swap_outs,
            r.swap_ins,
            r.prefix_hit_rate * 100.0,
            r.blocks_high_water,
            r.wall_s,
            r.tok_per_s
        );
        md.push_str(&format!(
            "| {} | {} | {} | {} | {} | {} | {} | {:.1}% | {} | {:.3} | {:.1} | {:016x} |\n",
            r.backend,
            r.peak_live_sessions,
            r.completed_turns,
            r.prefill_tokens,
            r.preemptions,
            r.swap_outs,
            r.swap_ins,
            r.prefix_hit_rate * 100.0,
            r.blocks_high_water,
            r.wall_s,
            r.tok_per_s,
            r.token_checksum
        ));
    }
    let ratio = rows[1].peak_live_sessions as f64 / rows[0].peak_live_sessions.max(1) as f64;
    println!(
        "paged sustains {ratio:.1}x the concurrent sessions of contiguous at the same budget \
         (checksums equal: both served identical tokens)"
    );
    md.push_str(&format!(
        "\nPaged sustains **{ratio:.1}x** the concurrent sessions of the contiguous baseline at \
         the same KV budget; token checksums are equal, so the comparison is between runs that \
         provably served identical streams. Protocol: `averis churn-bench --model {} --seed {} \
         --threads {}{}`.",
        args.get_or("model", "dense"),
        shape.seed,
        parallel::threads(),
        if smoke { " --smoke" } else { "" }
    ));
    let run = RunDir::create(&args.get_or("out", "runs"), "churn_bench")?;
    let mut csv = CsvSink::create(
        run.file("churn_bench.csv"),
        &[
            "backend_is_paged",
            "sessions",
            "turns",
            "completed_turns",
            "peak_live_sessions",
            "prefill_tokens",
            "generated",
            "preemptions",
            "swap_outs",
            "swap_ins",
            "prefix_hit_rate",
            "blocks_high_water",
            "wall_s",
            "tok_per_s",
        ],
    )?;
    for r in &rows {
        csv.row(&[
            if r.backend == "paged" { 1.0 } else { 0.0 },
            r.sessions as f64,
            r.turns as f64,
            r.completed_turns as f64,
            r.peak_live_sessions as f64,
            r.prefill_tokens as f64,
            r.generated as f64,
            r.preemptions as f64,
            r.swap_outs as f64,
            r.swap_ins as f64,
            r.prefix_hit_rate,
            r.blocks_high_water as f64,
            r.wall_s,
            r.tok_per_s,
        ])?;
    }
    println!("csv written to {}", run.file("churn_bench.csv").display());
    if let Some(record) = args.get("record") {
        record_markdown_block(record, "kv-paged", &md)?;
        println!("recorded churn table into {record}");
    }
    Ok(())
}

/// Signal plumbing for `averis serve`: SIGINT/SIGTERM set an atomic the
/// serve loop polls — the handler itself is async-signal-safe (one store,
/// nothing else), and the graceful drain runs on the main thread.
#[cfg(unix)]
mod sig {
    use std::sync::atomic::{AtomicBool, Ordering};

    static REQUESTED: AtomicBool = AtomicBool::new(false);

    extern "C" fn handle(_signum: i32) {
        REQUESTED.store(true, Ordering::SeqCst);
    }

    extern "C" {
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
    }

    pub fn install() {
        unsafe {
            signal(2, handle); // SIGINT (ctrl-c)
            signal(15, handle); // SIGTERM
        }
    }

    pub fn requested() -> bool {
        REQUESTED.load(Ordering::SeqCst)
    }
}

/// Non-unix fallback: no signal hooks; shutdown comes via `POST /v1/shutdown`.
#[cfg(not(unix))]
mod sig {
    pub fn install() {}

    pub fn requested() -> bool {
        false
    }
}

fn analyze_cmd(args: &CliArgs) -> Result<()> {
    let mut exp = experiment_from_args(args)?;
    // analysis wants the richest mean-bias signal: dense model, BF16 weights
    exp.recipe = QuantRecipe::Bf16;
    if args.get("steps").is_none() {
        exp.train.steps = 120;
    }
    figures::all_figures(&exp)
}

fn fig6_cmd(args: &CliArgs) -> Result<()> {
    let engine = args.get_or("engine", "sim");
    let base = experiment_from_args(args)?;
    let run = RunDir::create(&base.out_dir, "fig6")?;
    let mut summary: Vec<(QuantRecipe, f32, f32)> = Vec::new();
    if engine == "pjrt" {
        let store = ArtifactStore::open(args.get_or("artifacts", "artifacts"))?;
        let client = xla::PjRtClient::cpu()?;
        for recipe in QuantRecipe::PAPER_SET {
            println!("== {recipe} ==");
            let rdir = RunDir::create(&run.path, recipe.artifact_stem())?;
            let r = pjrt_train_run(
                &client,
                &store,
                recipe,
                base.train.steps,
                base.train.seed,
                base.corpus_seed,
                &rdir.path,
            )?;
            let fl = r.loss_curve.last().map(|&(_, l)| l).unwrap_or(f32::NAN);
            summary.push((recipe, fl, r.final_eval_loss));
        }
    } else {
        for recipe in QuantRecipe::PAPER_SET {
            println!("== {recipe} ==");
            let mut exp = base.clone();
            exp.recipe = recipe;
            exp.out_dir = run.path.to_string_lossy().to_string();
            let r = sim_train_run(&exp, false)?;
            summary.push((recipe, r.final_train_loss, r.final_eval_loss));
        }
    }
    // Fig-6-style summary with loss gaps vs BF16
    let bf16 = summary
        .iter()
        .find(|(r, _, _)| *r == QuantRecipe::Bf16)
        .map(|&(_, _, e)| e)
        .unwrap_or(f32::NAN);
    let mut csv = CsvSink::create(run.file("fig6_summary.csv"), &["recipe", "final_loss", "heldout", "gap_pct"])?;
    println!("\nFig. 6 summary ({} engine):", engine);
    println!("{:<18} {:>10} {:>10} {:>9}", "recipe", "train", "heldout", "gap%");
    for (r, tl, el) in &summary {
        let gap = 100.0 * (el - bf16) / bf16;
        csv.row_labeled(&r.to_string(), &[*tl as f64, *el as f64, gap as f64])?;
        println!("{:<18} {:>10.4} {:>10.4} {:>8.2}%", r.to_string(), tl, el, gap);
    }
    Ok(())
}

fn table1_cmd(args: &CliArgs) -> Result<()> {
    let base = experiment_from_args(args)?;
    let run = RunDir::create(&base.out_dir, "table1")?;
    let corpus = Corpus::generate(
        CorpusConfig { vocab: base.corpus.vocab, tokens: base.corpus.tokens, ..base.corpus },
        base.corpus_seed,
    );
    let n_probes = 60;
    let ctx = 32;
    let mut rows = Vec::new();
    for recipe in QuantRecipe::PAPER_SET {
        println!("== training {recipe} ==");
        let mut exp = base.clone();
        exp.recipe = recipe;
        exp.out_dir = run.path.to_string_lossy().to_string();
        let r = sim_train_run(&exp, false)?;
        // downstream: NVFP4 forward for low-bit rows, BF16 forward for BF16
        let eval_recipe =
            if recipe == QuantRecipe::Bf16 { QuantRecipe::Bf16 } else { QuantRecipe::Nvfp4 };
        let probes =
            evaluate_probes(exp.model_config(), &r.params, eval_recipe, &corpus, n_probes, ctx);
        rows.push((recipe, r.final_eval_loss, probes));
    }
    let bf16_loss = rows
        .iter()
        .find(|(r, _, _)| *r == QuantRecipe::Bf16)
        .map(|&(_, l, _)| l)
        .unwrap_or(f32::NAN);
    let mut csv = CsvSink::create(
        run.file("table1.csv"),
        &["recipe", "loss", "gap_pct", "cloze", "copy", "induction", "avg"],
    )?;
    println!("\nTable 1 (downstream probes in %, NVFP4 forward eval for FP4 rows):");
    println!(
        "{:<18} {:>8} {:>8} {:>8} {:>8} {:>10} {:>8}",
        "recipe", "loss", "gap%", "cloze", "copy", "induction", "avg"
    );
    for (recipe, loss, probes) in &rows {
        let gap = 100.0 * (loss - bf16_loss) / bf16_loss;
        let acc: Vec<f64> = probes.iter().map(|p| 100.0 * p.accuracy as f64).collect();
        let avg = 100.0 * mean_accuracy(probes) as f64;
        csv.row_labeled(
            &recipe.to_string(),
            &[*loss as f64, gap as f64, acc[0], acc[1], acc[2], avg],
        )?;
        println!(
            "{:<18} {:>8.4} {:>7.2}% {:>8.2} {:>8.2} {:>10.2} {:>8.2}",
            recipe.to_string(),
            loss,
            gap,
            acc[0],
            acc[1],
            acc[2],
            avg
        );
    }
    println!("\nwritten to {}", run.file("table1.csv").display());
    Ok(())
}
