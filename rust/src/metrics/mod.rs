//! Metric sinks: CSV writers for loss curves and analysis series, a tiny
//! JSON writer for run summaries, and wall-clock timers with mean/std.

use std::fmt::Write as _;
use std::fs;
use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::time::Instant;

/// Append-style CSV writer with a fixed header.
pub struct CsvSink {
    path: PathBuf,
    file: fs::File,
    cols: usize,
}

impl CsvSink {
    pub fn create(path: impl AsRef<Path>, header: &[&str]) -> std::io::Result<Self> {
        if let Some(dir) = path.as_ref().parent() {
            fs::create_dir_all(dir)?;
        }
        let mut file = fs::File::create(&path)?;
        writeln!(file, "{}", header.join(","))?;
        Ok(CsvSink { path: path.as_ref().to_path_buf(), file, cols: header.len() })
    }

    pub fn row(&mut self, values: &[f64]) -> std::io::Result<()> {
        assert_eq!(values.len(), self.cols, "csv row width mismatch");
        let mut line = String::new();
        for (i, v) in values.iter().enumerate() {
            if i > 0 {
                line.push(',');
            }
            let _ = write!(line, "{v}");
        }
        writeln!(self.file, "{line}")
    }

    pub fn row_labeled(&mut self, label: &str, values: &[f64]) -> std::io::Result<()> {
        assert_eq!(values.len() + 1, self.cols);
        let mut line = String::from(label);
        for v in values {
            let _ = write!(line, ",{v}");
        }
        writeln!(self.file, "{line}")
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

/// Minimal JSON object writer (flat string/number maps + nested objects),
/// enough for run summaries without serde.
#[derive(Default)]
pub struct JsonObj {
    parts: Vec<String>,
}

impl JsonObj {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn num(mut self, key: &str, v: f64) -> Self {
        self.parts.push(format!("\"{key}\": {v}"));
        self
    }

    pub fn int(mut self, key: &str, v: i64) -> Self {
        self.parts.push(format!("\"{key}\": {v}"));
        self
    }

    pub fn str(mut self, key: &str, v: &str) -> Self {
        self.parts.push(format!("\"{key}\": \"{}\"", v.replace('"', "\\\"")));
        self
    }

    pub fn obj(mut self, key: &str, v: JsonObj) -> Self {
        self.parts.push(format!("\"{key}\": {}", v.render()));
        self
    }

    pub fn render(&self) -> String {
        format!("{{{}}}", self.parts.join(", "))
    }

    pub fn write(&self, path: impl AsRef<Path>) -> std::io::Result<()> {
        if let Some(dir) = path.as_ref().parent() {
            fs::create_dir_all(dir)?;
        }
        fs::write(path, self.render())
    }
}

/// Timing statistics over repeated measurements.
#[derive(Clone, Debug, Default)]
pub struct TimingStats {
    pub samples_ms: Vec<f64>,
}

impl TimingStats {
    pub fn record(&mut self, ms: f64) {
        self.samples_ms.push(ms);
    }

    /// Time one closure invocation in ms and record it.
    pub fn time<R>(&mut self, f: impl FnOnce() -> R) -> R {
        let t = Instant::now();
        let r = f();
        self.record(t.elapsed().as_secs_f64() * 1e3);
        r
    }

    pub fn mean(&self) -> f64 {
        if self.samples_ms.is_empty() {
            return f64::NAN;
        }
        self.samples_ms.iter().sum::<f64>() / self.samples_ms.len() as f64
    }

    pub fn std(&self) -> f64 {
        if self.samples_ms.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.samples_ms.iter().map(|v| (v - m).powi(2)).sum::<f64>()
            / (self.samples_ms.len() - 1) as f64)
            .sqrt()
    }

    pub fn min(&self) -> f64 {
        self.samples_ms.iter().cloned().fold(f64::INFINITY, f64::min)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_writes_rows() {
        let dir = std::env::temp_dir().join("averis_test_csv");
        let path = dir.join("x.csv");
        {
            let mut s = CsvSink::create(&path, &["step", "loss"]).unwrap();
            s.row(&[0.0, 5.5]).unwrap();
            s.row(&[1.0, 5.2]).unwrap();
        }
        let text = fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 3);
        assert!(text.starts_with("step,loss"));
    }

    #[test]
    fn json_renders() {
        let j = JsonObj::new().str("name", "x").num("v", 1.5).int("n", 3);
        let s = j.render();
        assert!(s.contains("\"name\": \"x\""));
        assert!(s.contains("\"v\": 1.5"));
    }

    #[test]
    fn timing_stats() {
        let mut t = TimingStats::default();
        for v in [1.0, 2.0, 3.0] {
            t.record(v);
        }
        assert!((t.mean() - 2.0).abs() < 1e-9);
        assert!((t.std() - 1.0).abs() < 1e-9);
        assert_eq!(t.min(), 1.0);
    }
}
